"""Serving-engine tests: continuous batching, slot reuse, correctness of
engine output vs direct greedy decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

ARCH = ARCH_REGISTRY["qwen2-0.5b"].reduced()


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(ARCH, jax.random.PRNGKey(0), jnp.float32)
    return params


def direct_greedy(params, prompt, n_new, max_len=64):
    cache = M.init_cache(ARCH, 1, max_len, jnp.float32)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache, _ = M.prefill(params, ARCH, toks, cache)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = M.decode_step(
            params, ARCH, jnp.asarray([out[-1]], jnp.int32), pos, cache)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


class TestEngine:
    def test_single_request_matches_direct(self, setup):
        params = setup
        prompt, n_new = [3, 10, 7], 6
        expect = direct_greedy(params, prompt, n_new)
        engine = ServingEngine(ARCH, params, n_slots=2, max_len=64)
        reqs = [Request(uid=0, prompt=prompt, max_new_tokens=n_new)]
        engine.run(reqs)
        assert reqs[0].output == expect

    def test_more_requests_than_slots(self, setup):
        params = setup
        engine = ServingEngine(ARCH, params, n_slots=2, max_len=64)
        reqs = [Request(uid=i, prompt=[3 + i, 5], max_new_tokens=4)
                for i in range(5)]
        engine.run(reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.output) == 4 for r in reqs)

    def test_batched_equals_individual(self, setup):
        """Continuous batching must not change any request's output."""
        params = setup
        prompts = [[3, 10, 7], [4, 4], [9, 2, 11, 5]]
        expected = [direct_greedy(params, p, 4) for p in prompts]
        engine = ServingEngine(ARCH, params, n_slots=3, max_len=64)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        engine.run(reqs)
        for r, exp in zip(reqs, expected):
            assert r.output == exp, (r.uid, r.output, exp)

    def test_slot_reuse(self, setup):
        params = setup
        engine = ServingEngine(ARCH, params, n_slots=1, max_len=64)
        r1 = Request(uid=0, prompt=[3, 4], max_new_tokens=3)
        r2 = Request(uid=1, prompt=[5, 6], max_new_tokens=3)
        engine.run([r1, r2])
        assert r1.done and r2.done
        # slot 0 was reused; outputs are independent
        assert r2.output == direct_greedy(params, [5, 6], 3)


def _staggered_engine(params, lens, n_slots, max_new):
    engine = ServingEngine(ARCH, params, n_slots=n_slots, max_len=64)
    reqs = [Request(uid=i, prompt=list(range(3, 3 + ln)),
                    max_new_tokens=max_new)
            for i, ln in enumerate(lens)]
    for r in reqs:
        assert engine.add_request(r)
    return engine, reqs


class TestSchedulerRegressions:
    """Regressions for the step() position-group scheduler (ISSUE 8).

    Pre-fix, step() recomputed each position group from *live*
    ``self.positions`` while mutating them inside the loop: a slot
    advanced from p to p+1 was decoded again whenever p+1 was also in
    the snapshot set (double decode), and a slot that finished mid-step
    stayed in ``active`` so a later group dereferenced its freed
    ``slot_req`` entry (AttributeError).
    """

    def test_one_token_per_active_slot_per_step(self, setup):
        # staggered prompt lengths -> distinct position groups (2/3/4)
        engine, reqs = _staggered_engine(setup, [2, 3, 4], 3, 6)
        n_steps = 0
        while any(r is not None for r in engine.slot_req):
            before = {r.uid: len(r.output) for r in reqs}
            active = [r for r in engine.slot_req if r is not None]
            engine.step()
            n_steps += 1
            for r in active:
                gained = len(r.output) - before[r.uid]
                assert gained == 1, (
                    f"slot of uid={r.uid} gained {gained} tokens in one "
                    f"step (double decode)")
            assert n_steps < 64
        assert all(r.done and len(r.output) == 6 for r in reqs)

    def test_mid_step_finish_does_not_crash(self, setup):
        # uid 0 (prompt len 2) finishes while uid 1 (len 3) is still
        # active one position ahead: pre-fix the freed slot re-entered
        # the pos-3 group and step() crashed on slot_req[i] == None
        engine, reqs = _staggered_engine(setup, [2, 3], 2, 2)
        for _ in range(8):
            engine.step()
        assert all(r.done for r in reqs)
        assert all(len(r.output) == 2 for r in reqs)

    def test_run_surfaces_exhaustion(self, setup):
        engine = ServingEngine(ARCH, setup, n_slots=2, max_len=64)
        reqs = [Request(uid=0, prompt=[3, 4], max_new_tokens=8)]
        with pytest.warns(RuntimeWarning, match="exhaust"):
            engine.run(reqs, max_steps=2)
        assert engine.last_run_exhausted
        assert not reqs[0].done
        # a completing run leaves the flag clear
        engine2 = ServingEngine(ARCH, setup, n_slots=2, max_len=64)
        reqs2 = [Request(uid=1, prompt=[3, 4], max_new_tokens=3)]
        engine2.run(reqs2)
        assert engine2.last_run_exhausted is False
        assert reqs2[0].done


def _marked(cache, sign):
    """Fill every leaf with distinct values (sign flips the range)."""
    return jax.tree_util.tree_map(
        lambda x: (sign * (1.0 + jnp.arange(x.size, dtype=jnp.float32))
                   ).reshape(x.shape).astype(x.dtype), cache)


class TestCacheSplice:
    """Per-leaf batch-axis splicing over every init_cache leaf shape.

    ``add_request`` used to hardcode batch axis 1 with a per-slot width
    of 1, and step()'s splice fell back to clobbering any low-rank leaf
    wholesale. The SSD state leaves fold batch with heads —
    ``(layers, B*h, n, pd)`` — so both assumptions are wrong for the
    mamba2/hymba registry entries.
    """

    N_SLOTS, MAX_LEN = 4, 16

    def test_registry_has_folded_batch_leaves(self):
        from repro.serving import engine as eng
        pers = set()
        for name in ARCH_REGISTRY:
            arch = ARCH_REGISTRY[name].reduced()
            for _, per in eng.cache_batch_axes(arch, self.N_SLOTS,
                                               self.MAX_LEN, jnp.float32):
                if per is not None:
                    pers.add(per)
        # the guard exists because at least one leaf shape folds extra
        # state into the batch axis (per-slot width > 1)
        assert any(p > 1 for p in pers), pers

    @pytest.mark.parametrize("name", sorted(ARCH_REGISTRY))
    def test_splice_touches_only_target_rows(self, name):
        from repro.serving import engine as eng
        arch = ARCH_REGISTRY[name].reduced()
        full = _marked(M.init_cache(arch, self.N_SLOTS, self.MAX_LEN,
                                    jnp.float32), 1.0)
        axes = eng.cache_batch_axes(arch, self.N_SLOTS, self.MAX_LEN,
                                    jnp.float32)
        leaves = jax.tree_util.tree_leaves(full)
        assert len(axes) == len(leaves)
        for leaf, (axis, per) in zip(leaves, axes):
            assert axis is not None and per >= 1
            assert leaf.shape[axis] == self.N_SLOTS * per

        # single-slot splice (the add_request path)
        row = _marked(M.init_cache(arch, 1, self.MAX_LEN, jnp.float32), -1.0)
        slot = 2
        spliced = eng.splice_slot(full, row, axes, slot)
        for f, r, s, (axis, per) in zip(
                leaves, jax.tree_util.tree_leaves(row),
                jax.tree_util.tree_leaves(spliced), axes):
            fm = np.moveaxis(np.asarray(f), axis, 0)
            rm = np.moveaxis(np.asarray(r), axis, 0)
            sm = np.moveaxis(np.asarray(s), axis, 0)
            lo, hi = slot * per, (slot + 1) * per
            np.testing.assert_array_equal(sm[lo:hi], rm)
            np.testing.assert_array_equal(sm[:lo], fm[:lo])
            np.testing.assert_array_equal(sm[hi:], fm[hi:])

        # position-group splice (the step() path): slots {1, 3}
        new = _marked(full, -1.0)
        slots = np.asarray([1, 3])
        out = eng.splice_rows(full, new, axes, slots)
        for f, n_, o, (axis, per) in zip(
                leaves, jax.tree_util.tree_leaves(new),
                jax.tree_util.tree_leaves(out), axes):
            fm = np.moveaxis(np.asarray(f), axis, 0)
            nm = np.moveaxis(np.asarray(n_), axis, 0)
            om = np.moveaxis(np.asarray(o), axis, 0)
            for s in range(self.N_SLOTS):
                lo, hi = s * per, (s + 1) * per
                want = nm[lo:hi] if s in (1, 3) else fm[lo:hi]
                np.testing.assert_array_equal(om[lo:hi], want)
