"""Serving-engine tests: continuous batching, slot reuse, correctness of
engine output vs direct greedy decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

ARCH = ARCH_REGISTRY["qwen2-0.5b"].reduced()


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(ARCH, jax.random.PRNGKey(0), jnp.float32)
    return params


def direct_greedy(params, prompt, n_new, max_len=64):
    cache = M.init_cache(ARCH, 1, max_len, jnp.float32)
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache, _ = M.prefill(params, ARCH, toks, cache)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = M.decode_step(
            params, ARCH, jnp.asarray([out[-1]], jnp.int32), pos, cache)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


class TestEngine:
    def test_single_request_matches_direct(self, setup):
        params = setup
        prompt, n_new = [3, 10, 7], 6
        expect = direct_greedy(params, prompt, n_new)
        engine = ServingEngine(ARCH, params, n_slots=2, max_len=64)
        reqs = [Request(uid=0, prompt=prompt, max_new_tokens=n_new)]
        engine.run(reqs)
        assert reqs[0].output == expect

    def test_more_requests_than_slots(self, setup):
        params = setup
        engine = ServingEngine(ARCH, params, n_slots=2, max_len=64)
        reqs = [Request(uid=i, prompt=[3 + i, 5], max_new_tokens=4)
                for i in range(5)]
        engine.run(reqs)
        assert all(r.done for r in reqs)
        assert all(len(r.output) == 4 for r in reqs)

    def test_batched_equals_individual(self, setup):
        """Continuous batching must not change any request's output."""
        params = setup
        prompts = [[3, 10, 7], [4, 4], [9, 2, 11, 5]]
        expected = [direct_greedy(params, p, 4) for p in prompts]
        engine = ServingEngine(ARCH, params, n_slots=3, max_len=64)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        engine.run(reqs)
        for r, exp in zip(reqs, expected):
            assert r.output == exp, (r.uid, r.output, exp)

    def test_slot_reuse(self, setup):
        params = setup
        engine = ServingEngine(ARCH, params, n_slots=1, max_len=64)
        r1 = Request(uid=0, prompt=[3, 4], max_new_tokens=3)
        r2 = Request(uid=1, prompt=[5, 6], max_new_tokens=3)
        engine.run([r1, r2])
        assert r1.done and r2.done
        # slot 0 was reused; outputs are independent
        assert r2.output == direct_greedy(params, [5, 6], 3)
