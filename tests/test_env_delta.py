"""ISSUE-7 tentpole (b): cache-carried (delta-priced) env stepping.

Placement episodes thread a PlacementCtx + PlacementEvalCache through
EnvState so each step prices one floorplan move with a fused
``nop_stats_delta(move_kinds='both')`` instead of a full
``costmodel.evaluate``. The contract tested here: across 50-step
episodes the delta-priced step agrees with a scratch ``evaluate`` of the
same mutated floorplan on EVERY ``Metrics`` field to 1e-5, the default
(non-placement) env pytree is unchanged, and PPO trains on the
placement-episode observation/action space.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import placement as pm
from repro.rl import ppo


def _cfgs(episode_len=50):
    mk = lambda delta: chipenv.EnvConfig(placement_episode=True,
                                         delta_eval=delta,
                                         episode_len=episode_len)
    return mk(True), mk(False)


def _actions(key, n):
    heads = jnp.asarray(ps.PLACEMENT_HEAD_SIZES, jnp.int32)
    return jax.random.randint(key, (n, len(ps.PLACEMENT_HEAD_SIZES)), 0,
                              heads, dtype=jnp.int32)


class TestPlacementEpisodePricing:
    def test_reset_bit_equal_between_modes(self):
        d_cfg, s_cfg = _cfgs()
        key = jax.random.PRNGKey(0)
        sd, od = chipenv.reset(key, d_cfg)
        ss, os_ = chipenv.reset(key, s_cfg)
        np.testing.assert_array_equal(np.asarray(od), np.asarray(os_))
        np.testing.assert_array_equal(
            np.asarray(sd.cache.placement.chiplet_cell),
            np.asarray(ss.cache.placement.chiplet_cell))

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_delta_vs_scratch_50_steps_all_metrics(self, seed):
        """The acceptance contract: every Metrics field to 1e-5 on every
        step of a 50-step episode, against BOTH the scratch-mode env and
        an independently maintained apply_action + evaluate oracle."""
        d_cfg, s_cfg = _cfgs()
        key = jax.random.PRNGKey(seed)
        sd, _ = chipenv.reset(key, d_cfg)
        ss, _ = chipenv.reset(key, s_cfg)
        acts = _actions(jax.random.fold_in(key, 1), 50)
        scen = d_cfg.scenario()
        d_step = jax.jit(lambda st, a: chipenv.step(st, a, d_cfg))
        s_step = jax.jit(lambda st, a: chipenv.step(st, a, s_cfg))
        design = sd.design
        v = ps.decode(design)
        n_pos = cm.footprint_positions(v)
        plc = sd.cache.placement
        for i in range(50):
            sd, od, rd, dd, md = d_step(sd, acts[i])
            ss, os_, rs, ds, ms = s_step(ss, acts[i])
            # the independent oracle never touches the env's cache
            plc = pm.apply_action(plc, acts[i], n_pos)
            mo = cm.evaluate(design, scen.workload, scen.weights, d_cfg.hw,
                             placement=plc)
            for field in cm.Metrics._fields:
                a = float(getattr(md, field))
                np.testing.assert_allclose(
                    a, float(getattr(ms, field)), rtol=1e-5, atol=1e-5,
                    err_msg=f"step {i} vs scratch env: {field}")
                np.testing.assert_allclose(
                    a, float(getattr(mo, field)), rtol=1e-5, atol=1e-5,
                    err_msg=f"step {i} vs oracle: {field}")
            np.testing.assert_allclose(np.asarray(od), np.asarray(os_),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"step {i}: obs")
            assert bool(dd) == bool(ds)
            np.testing.assert_array_equal(
                np.asarray(sd.cache.placement.chiplet_cell),
                np.asarray(plc.chiplet_cell), err_msg=f"step {i}: cells")
        assert bool(dd)   # episode_len=50 -> last step terminates

    @pytest.mark.parametrize("head", [1, 3])
    def test_out_of_space_cell_targets_price_as_clipped(self, head):
        """Both grid-cell heads normalize out-of-space targets.

        _step_placement clipped the HBM target (a[3]) but passed the
        chiplet target (a[1]) unclipped into PlacementMove, leaning on
        relocate_chiplet's internal clamp to stay in-grid. The env-layer
        clip pins the contract where the action is decoded: an
        out-of-range index on EITHER head prices and mutates exactly
        like its clipped twin (N_CELLS - 1), on the delta path and in
        agreement with the scratch path."""
        d_cfg, s_cfg = _cfgs()
        key = jax.random.PRNGKey(7)
        base = _actions(jax.random.fold_in(key, 1), 1)[0]
        wild = base.at[head].set(pm.N_CELLS + 173)
        clipped = base.at[head].set(pm.N_CELLS - 1)
        d_step = jax.jit(lambda st, a: chipenv.step(st, a, d_cfg))
        s_step = jax.jit(lambda st, a: chipenv.step(st, a, s_cfg))
        outs = {}
        for name, act in (("wild", wild), ("clipped", clipped)):
            sd, _ = chipenv.reset(key, d_cfg)
            sd, od, rd, _, _ = d_step(sd, act)
            outs[name] = (np.asarray(od), float(rd),
                          np.asarray(sd.cache.placement.chiplet_cell),
                          np.asarray(sd.cache.placement.hbm_ij))
        for a, b in zip(outs["wild"], outs["clipped"]):
            np.testing.assert_array_equal(a, b)
        ss, _ = chipenv.reset(key, s_cfg)
        _, _, rs, _, _ = s_step(ss, wild)
        np.testing.assert_allclose(outs["wild"][1], float(rs),
                                   rtol=1e-5, atol=1e-5)

    def test_auto_reset_equivalence_across_boundary(self):
        """auto_reset_step agrees between pricing modes through an
        episode boundary (fresh cache on reset in both)."""
        d_cfg, s_cfg = _cfgs(episode_len=5)
        key = jax.random.PRNGKey(7)
        sd, _ = chipenv.reset(key, d_cfg)
        ss, _ = chipenv.reset(key, s_cfg)
        acts = _actions(jax.random.fold_in(key, 2), 12)
        d_step = jax.jit(lambda st, a: chipenv.auto_reset_step(st, a, d_cfg))
        s_step = jax.jit(lambda st, a: chipenv.auto_reset_step(st, a, s_cfg))
        dones = []
        for i in range(12):
            sd, od, rd, dd, _ = d_step(sd, acts[i])
            ss, os_, rs, ds, _ = s_step(ss, acts[i])
            np.testing.assert_allclose(float(rd), float(rs), rtol=1e-5,
                                       atol=1e-5, err_msg=f"step {i}")
            np.testing.assert_allclose(np.asarray(od), np.asarray(os_),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"step {i}")
            dones.append(bool(dd))
        assert dones[4] and dones[9]          # two boundaries crossed

    def test_vmapped_episode_scan(self):
        """The PPO rollout shape: scan of vmapped auto_reset_step runs
        jitted with finite rewards in both pricing modes, agreeing."""
        d_cfg, s_cfg = _cfgs(episode_len=8)
        n_env, n_steps = 3, 16
        keys = jax.random.split(jax.random.PRNGKey(9), n_env)
        acts = jax.random.randint(
            jax.random.PRNGKey(10), (n_steps, n_env, 4), 0,
            jnp.asarray(ps.PLACEMENT_HEAD_SIZES, jnp.int32),
            dtype=jnp.int32)

        def rollout(cfg):
            states, _ = jax.vmap(lambda k: chipenv.reset(k, cfg))(keys)

            def body(st, a):
                st, _, r, d, _ = jax.vmap(
                    lambda s, ai: chipenv.auto_reset_step(s, ai, cfg))(st, a)
                return st, (r, d)

            _, (rews, dones) = jax.lax.scan(body, states, acts)
            return rews, dones

        rd, dd = jax.jit(lambda: rollout(d_cfg))()
        rs, ds = jax.jit(lambda: rollout(s_cfg))()
        assert bool(jnp.isfinite(rd).all())
        np.testing.assert_allclose(np.asarray(rd), np.asarray(rs),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(ds))

    @pytest.mark.parametrize("placement", [False, True])
    def test_auto_reset_step_vec_matches_per_env(self, placement):
        """auto_reset_step_vec (cond-gated batched reset, the PPO
        placement-rollout fast path) matches vmapped per-env
        auto_reset_step: rewards, dones, final cache cells and key
        streams are bit-identical; observations agree bitwise off
        episode boundaries and to 1e-5 at them (the separately compiled
        cond reset branch can move a boundary obs feature by an ulp,
        which is why ppo.collect_rollout only routes placement episodes
        through the vec path — the classic design env keeps the per-env
        path and its PR-4 recorded trajectories bit-exact)."""
        if placement:
            cfg = chipenv.EnvConfig(placement_episode=True, delta_eval=True,
                                    episode_len=5)
            n_act = len(ps.PLACEMENT_HEAD_SIZES)
            highs = jnp.asarray(ps.PLACEMENT_HEAD_SIZES, jnp.int32)
        else:
            cfg = chipenv.EnvConfig(episode_len=5)
            n_act = len(ps.HEAD_SIZES)
            highs = jnp.asarray(ps.HEAD_SIZES, jnp.int32)
        n_env, n_steps = 3, 12                 # crosses two boundaries
        keys = jax.random.split(jax.random.PRNGKey(13), n_env)
        acts = jax.random.randint(jax.random.PRNGKey(14),
                                  (n_steps, n_env, n_act), 0, highs,
                                  dtype=jnp.int32)

        def rollout(vec):
            states, _ = jax.vmap(lambda k: chipenv.reset(k, cfg))(keys)

            def body(st, a):
                if vec:
                    st, o, r, d, _ = chipenv.auto_reset_step_vec(st, a, cfg)
                else:
                    st, o, r, d, _ = jax.vmap(
                        lambda s, ai: chipenv.auto_reset_step(
                            s, ai, cfg))(st, a)
                return st, (o, r, d)

            final, out = jax.lax.scan(body, states, acts)
            return final, out

        fs_v, (ov, rv, dv) = jax.jit(lambda: rollout(True))()
        fs_p, (op, rp, dp_) = jax.jit(lambda: rollout(False))()
        dones = np.asarray(dv)
        np.testing.assert_array_equal(dones, np.asarray(dp_))
        np.testing.assert_array_equal(np.asarray(rv), np.asarray(rp))
        ov, op = np.asarray(ov), np.asarray(op)
        np.testing.assert_array_equal(ov[~dones], op[~dones])
        np.testing.assert_allclose(ov[dones], op[dones],
                                   rtol=1e-5, atol=1e-5)
        if placement:
            np.testing.assert_array_equal(
                np.asarray(fs_v.cache.placement.chiplet_cell),
                np.asarray(fs_p.cache.placement.chiplet_cell))
        np.testing.assert_array_equal(np.asarray(fs_v.key),
                                      np.asarray(fs_p.key))

    def test_default_env_pytree_unchanged(self):
        """Non-placement episodes: EnvState keeps ctx/cache at None (the
        PR-4 pytree structure), spaces unchanged."""
        cfg = chipenv.EnvConfig()
        state, obs = chipenv.reset(jax.random.PRNGKey(1), cfg)
        assert state.ctx is None and state.cache is None
        assert chipenv.head_sizes(cfg) == ps.HEAD_SIZES
        assert obs.shape == (chipenv.obs_dim(cfg),)
        p_cfg = chipenv.EnvConfig(placement_episode=True)
        assert chipenv.head_sizes(p_cfg) == ps.PLACEMENT_HEAD_SIZES
        assert chipenv.obs_dim(p_cfg) == 13
        assert chipenv.action_dim(p_cfg) == 4

    def test_batched_action_raises(self):
        cfg = chipenv.EnvConfig(placement_episode=True)
        state, _ = chipenv.reset(jax.random.PRNGKey(2), cfg)
        with pytest.raises(ValueError, match="vmap"):
            chipenv.step(state, jnp.zeros((2, 4), jnp.int32), cfg)


class TestPPOPlacementEpisodes:
    CFG = ppo.PPOConfig(n_envs=2, n_steps=8, n_epochs=1, batch_size=8)

    def test_train_runs_and_shapes(self):
        env_cfg = chipenv.EnvConfig(placement_episode=True, episode_len=8)
        res = ppo.train(jax.random.PRNGKey(0), env_cfg=env_cfg,
                        cfg=self.CFG, total_timesteps=32)
        assert res.best_action.shape == (4,)
        assert np.isfinite(float(res.best_reward))

    def test_greedy_design_raises_without_design_heads(self):
        env_cfg = chipenv.EnvConfig(placement_episode=True, episode_len=8)
        res = ppo.train(jax.random.PRNGKey(1), env_cfg=env_cfg,
                        cfg=self.CFG, total_timesteps=32)
        with pytest.raises(ValueError, match="placement-episode"):
            ppo.greedy_design(res.params, env_cfg)
