"""HLO analyzer + roofline tests (the §Roofline measurement machinery)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as H
from repro.analysis import roofline as RL
from repro.configs import ARCH_REGISTRY
from repro.configs.base import ShapeConfig


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestProgramCosts:
    def test_flat_matmul(self):
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        txt = _compile_text(lambda x, y: x @ y, a, a)
        pc = H.program_costs(txt)
        np.testing.assert_allclose(pc.flops, 2 * 128 ** 3, rtol=1e-6)

    def test_scan_trip_count_multiplies(self):
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=11)[0]

        pc = H.program_costs(_compile_text(f, a))
        np.testing.assert_allclose(pc.flops, 11 * 2 * 128 ** 3, rtol=1e-6)
        assert pc.n_whiles == 1
        assert pc.unknown_trip_whiles == 0

    def test_nested_scan(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def f(x):
            def outer(c, _):
                c2 = jax.lax.scan(lambda c, _: (c @ c, None), c, None,
                                  length=3)[0]
                return c2, None
            return jax.lax.scan(outer, x, None, length=5)[0]

        pc = H.program_costs(_compile_text(f, a))
        np.testing.assert_allclose(pc.flops, 15 * 2 * 64 ** 3, rtol=1e-6)

    def test_xla_cost_analysis_misses_scans(self):
        """Documents WHY we parse HLO: XLA reports the body once."""
        a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                                length=8)[0]

        compiled = jax.jit(f).lower(a).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jaxlibs wrap in a list
            ca = ca[0]
        xla_flops = ca.get("flops", 0.0)
        ours = H.program_costs(compiled.as_text()).flops
        assert ours == pytest.approx(8 * xla_flops, rel=1e-6)

    def test_bytes_by_kind_present(self):
        a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        pc = H.program_costs(_compile_text(lambda x, y: x @ y + 1.0, a, a))
        assert pc.bytes > 0
        assert "dot" in pc.bytes_by_kind

    def test_dynamic_slice_counts_slice_only(self):
        big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

        def f(x):
            return jax.lax.dynamic_slice(x, (0, 0), (8, 8))

        pc = H.program_costs(_compile_text(f, big))
        # the 4 MB source must NOT be charged; only ~2x 256 B slice
        assert pc.bytes < 1024 * 1024


class TestCollectiveBytes:
    def test_psum_counted(self):
        import os
        if len(jax.devices()) < 2:
            pytest.skip("needs >1 device (see test_distributed.py)")

    def test_collective_parse_synthetic(self):
        hlo = """
HloModule test
ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  ROOT %out = f32[128,256]{1,0} copy(%ar)
}
"""
        total, breakdown = H.collective_bytes(hlo)
        assert breakdown.get("all-reduce") == 128 * 256 * 4


class TestRoofline:
    def test_model_flops(self):
        cfg = ARCH_REGISTRY["llama3-8b"]
        train = ShapeConfig("train_4k", 4096, 256, "train")
        mf = RL.model_flops(cfg, train)
        expect = 6.0 * cfg.active_param_count() * 4096 * 256
        assert mf == pytest.approx(expect)
        decode = ShapeConfig("decode_32k", 32768, 128, "decode")
        assert RL.model_flops(cfg, decode) == pytest.approx(
            2.0 * cfg.active_param_count() * 128)

    def test_moe_uses_active_params(self):
        cfg = ARCH_REGISTRY["qwen3-moe-235b-a22b"]
        shape = ShapeConfig("train_4k", 4096, 256, "train")
        mf = RL.model_flops(cfg, shape)
        assert mf < 6.0 * cfg.param_count() * 4096 * 256 * 0.2

    def test_report_roundtrip(self, tmp_path):
        cfg = ARCH_REGISTRY["qwen2-0.5b"]
        shape = ShapeConfig("train_4k", 4096, 256, "train")
        rep = RL.analyze(cfg, shape, "pod16x16", 256,
                         {"flops": 1e12, "bytes accessed": 1e9},
                         "ENTRY %m (p: f32[8]) -> f32[8] { ROOT %p = "
                         "f32[8]{0} parameter(0) }")
        path = str(tmp_path / "r.json")
        RL.save_reports([rep], path)
        back = RL.load_reports(path)[0]
        assert back.arch == rep.arch
        assert back.t_compute == pytest.approx(rep.t_compute)

    def test_format_table(self):
        cfg = ARCH_REGISTRY["qwen2-0.5b"]
        shape = ShapeConfig("train_4k", 4096, 256, "train")
        rep = RL.analyze(cfg, shape, "pod16x16", 256, {},
                         "ENTRY %m (p: f32[8]) -> f32[8] { ROOT %p = "
                         "f32[8]{0} parameter(0) }")
        table = RL.format_table([rep])
        assert "qwen2-0.5b" in table and "bottleneck" in table
