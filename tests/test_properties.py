"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis import hlo as hlo_lib
from repro.core import costmodel as cm
from repro.core import params as ps
from repro.core import placement as pm
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.training import compression as comp

# small deadline budget: every example runs jitted numpy-ish code
_SETTINGS = dict(max_examples=25, deadline=None)


def design_strategy():
    return st.tuples(*[st.integers(0, h - 1) for h in ps.HEAD_SIZES])


class TestCostModelProperties:
    @given(design_strategy())
    @settings(**_SETTINGS)
    def test_metrics_finite_positive(self, idx):
        dp = ps.from_flat(jnp.asarray(idx, jnp.int32))
        m = cm.evaluate(dp)
        assert np.isfinite(float(m.reward))
        assert float(m.eff_tops) > 0
        assert 0 < float(m.u_sys) <= 1.0 + 1e-6
        assert 0 < float(m.die_yield) <= 1.0
        assert float(m.die_area_mm2) <= 400.0 + 1e-3
        assert float(m.eff_tops) <= float(m.peak_tops) + 1e-3

    @given(design_strategy())
    @settings(**_SETTINGS)
    def test_codec_roundtrip(self, idx):
        flat = jnp.asarray(idx, jnp.int32)
        back = ps.to_flat(ps.from_flat(flat))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(idx))

    @given(design_strategy(), st.integers(1, 127))
    @settings(**_SETTINGS)
    def test_more_links_never_reduce_utilization(self, idx, bump):
        dp = ps.from_flat(jnp.asarray(idx, jnp.int32))
        hi = dp._replace(hbm_links_2p5d=jnp.minimum(
            dp.hbm_links_2p5d + bump, 99))
        u_lo = float(cm.evaluate(dp).u_sys)
        u_hi = float(cm.evaluate(hi).u_sys)
        assert u_hi >= u_lo - 1e-6

    @given(design_strategy())
    @settings(**_SETTINGS)
    def test_reward_decomposition(self, idx):
        dp = ps.from_flat(jnp.asarray(idx, jnp.int32))
        m = cm.evaluate(dp)
        expect = float(m.reward_t) - float(m.reward_c) - 0.1 * float(m.reward_e)
        np.testing.assert_allclose(float(m.reward), expect, rtol=1e-5)

    @given(st.floats(1.0, 800.0), st.floats(0.01, 0.5))
    @settings(**_SETTINGS)
    def test_yield_bounds(self, area, d):
        y = float(cm.die_yield(jnp.float32(area), d))
        assert 0.0 < y <= 1.0
        y2 = float(cm.die_yield(jnp.float32(area * 2), d))
        assert y2 < y                      # strictly worse at larger area


class TestNoPProperties:
    """Invariants of the pairwise-traffic NoP reduction and its two-tier
    dispatch (core/placement.py), over randomized placements/designs."""

    @staticmethod
    def _random_placement(rng, n_pos):
        cells = rng.choice(pm.N_CELLS, size=n_pos, replace=False)
        cells = np.concatenate(
            [cells, rng.randint(0, pm.N_CELLS, pm.MAX_SLOTS - n_pos)])
        hbm_ij = rng.uniform(-1.0, 16.0, (pm.N_HBM, 2)).astype(np.float32)
        return pm.Placement(chiplet_cell=jnp.asarray(cells, jnp.int32),
                            hbm_ij=jnp.asarray(hbm_ij))

    @given(st.integers(1, 128), st.integers(1, 63), st.integers(0, 2),
           st.integers(0, 2**31 - 1))
    @settings(**_SETTINGS)
    def test_mean_hops_invariant_under_slot_relabeling(
            self, n_pos, mask, arch, seed):
        """Permuting which slot index sits on which cell must not change
        any traffic-weighted statistic (the traffic model is anonymous)."""
        rng = np.random.RandomState(seed)
        plc = self._random_placement(rng, n_pos)
        perm = np.arange(pm.MAX_SLOTS)
        perm[:n_pos] = rng.permutation(n_pos)
        plc_p = plc._replace(chiplet_cell=plc.chiplet_cell[perm])
        a = pm.nop_stats(plc, jnp.float32(n_pos), jnp.int32(mask),
                         jnp.float32(arch))
        b = pm.nop_stats(plc_p, jnp.float32(n_pos), jnp.int32(mask),
                         jnp.float32(arch))
        for field in pm.NoPStats._fields:
            np.testing.assert_allclose(
                float(getattr(a, field)), float(getattr(b, field)),
                rtol=1e-5, atol=1e-5, err_msg=field)

    @given(st.integers(1, 128), st.integers(1, 63), st.integers(0, 2),
           st.integers(0, 2**31 - 1))
    @settings(**_SETTINGS)
    def test_floors_worst_mean_contention(self, n_pos, mask, arch, seed):
        """hbm_floors respected; worst >= mean; contention >= 0."""
        rng = np.random.RandomState(seed)
        plc = self._random_placement(rng, n_pos)
        stats = pm.nop_stats(plc, jnp.float32(n_pos), jnp.int32(mask),
                             jnp.float32(arch))
        floors = np.asarray(pm.hbm_floors(jnp.int32(mask),
                                          jnp.float32(arch)))
        placed = np.asarray([(mask >> b) & 1 for b in range(pm.N_HBM)]) > 0
        min_floor = floors[placed].min()
        assert float(stats.hops_hbm_mean) >= min_floor - 1e-6
        assert float(stats.hops_hbm_worst) >= min_floor - 1e-6
        assert (float(stats.hops_hbm_worst)
                >= float(stats.hops_hbm_mean) - 1e-5)
        assert (float(stats.hops_ai_worst)
                >= float(stats.hops_ai_mean) - 1e-5)
        assert float(stats.link_contention) >= 0.0
        assert float(stats.region_edges) >= 0.0

    @given(st.integers(1, 128), st.integers(1, 63), st.integers(0, 2))
    @settings(**_SETTINGS)
    def test_fast_tier_equals_full_tier_on_canonical(self, n_pos, mask,
                                                     arch):
        """nop_stats_fast(m, n, ...) == nop_stats(canonical(m, n, ...))
        for randomized (m, n, hbm_mask, arch_type)."""
        m, n = cm.mesh_dims(jnp.int32(n_pos))
        plc = pm.canonical(m, n, jnp.int32(mask), jnp.float32(arch))
        full = pm.nop_stats(plc, jnp.float32(n_pos), jnp.int32(mask),
                            jnp.float32(arch))
        fast = pm.nop_stats_fast(m, n, jnp.float32(n_pos), jnp.int32(mask),
                                 jnp.float32(arch))
        for field in pm.NoPStats._fields:
            np.testing.assert_allclose(
                float(getattr(fast, field)), float(getattr(full, field)),
                rtol=1e-5, atol=1e-5, err_msg=field)

    @given(design_strategy())
    @settings(**_SETTINGS)
    def test_evaluate_tiers_agree(self, idx):
        """The dispatching evaluate(): fast == full reward to 1e-5."""
        dp = ps.from_flat(jnp.asarray(idx, jnp.int32))
        r_fast = float(cm.evaluate(dp, nop_fidelity="fast").reward)
        r_full = float(cm.evaluate(dp, nop_fidelity="full").reward)
        np.testing.assert_allclose(r_fast, r_full, rtol=1e-5, atol=1e-4)


class TestDeltaProperties:
    """Algebra of the delta-evaluated placement cache (ISSUE 4):
    delta-then-inverse restores the cached stats; commuting moves on
    disjoint slots are order-independent."""

    @staticmethod
    def _setup(design_seed, place_seed):
        dp = ps.from_flat(jnp.asarray(design_seed, jnp.int32))
        v = ps.decode(dp)
        n_pos = cm.footprint_positions(v)
        rng = np.random.RandomState(place_seed)
        act = int(n_pos)
        cells = rng.choice(pm.N_CELLS, size=act, replace=False)
        cells = np.concatenate(
            [cells, rng.randint(0, pm.N_CELLS, pm.MAX_SLOTS - act)])
        hbm_ij = rng.uniform(-1.0, 16.0, (pm.N_HBM, 2)).astype(np.float32)
        plc = pm.Placement(chiplet_cell=jnp.asarray(cells, jnp.int32),
                           hbm_ij=jnp.asarray(hbm_ij))
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type)
        return v, n_pos, plc, cache, rng

    @staticmethod
    def _apply(cache, mv, n_pos, v):
        cand = pm.nop_stats_delta(cache, mv, n_pos, v.hbm_mask, v.arch_type)
        return pm.commit_move(cache, cand, True)

    @staticmethod
    def _free_cells(cells, act, rng, k):
        free = np.setdiff1d(np.arange(pm.N_CELLS), cells[:act])
        return rng.choice(free, size=k, replace=False)

    @given(design_strategy(), st.integers(0, 2**31 - 1),
           st.booleans())
    @settings(**_SETTINGS)
    def test_inverse_move_restores_cache(self, idx, seed, use_hbm):
        """Applying a move and then its inverse restores every cached
        stat (and the placement) exactly."""
        v, n_pos, plc, cache, rng = self._setup(idx, seed)
        act = int(n_pos)
        if use_hbm:
            b = rng.randint(0, pm.N_HBM)
            old_anchor = np.asarray(plc.hbm_ij)[b]
            mv = pm.PlacementMove(
                kind=jnp.int32(1), slot=jnp.int32(0), cell=jnp.int32(0),
                hbm=jnp.int32(b),
                anchor=jnp.asarray(rng.uniform(-1.0, 16.0, 2), jnp.float32))
            inv = mv._replace(anchor=jnp.asarray(old_anchor, jnp.float32))
        else:
            s = rng.randint(0, act)
            old_cell = int(np.asarray(plc.chiplet_cell)[s])
            tgt = int(self._free_cells(
                np.asarray(plc.chiplet_cell), act, rng, 1)[0])
            mv = pm.PlacementMove(
                kind=jnp.int32(0), slot=jnp.int32(s), cell=jnp.int32(tgt),
                hbm=jnp.int32(0), anchor=jnp.zeros(2, jnp.float32))
            inv = mv._replace(cell=jnp.int32(old_cell))
        restored = self._apply(self._apply(cache, mv, n_pos, v),
                               inv, n_pos, v)
        for field in pm.NoPStats._fields:
            np.testing.assert_allclose(
                float(getattr(restored.stats, field)),
                float(getattr(cache.stats, field)),
                rtol=1e-5, atol=1e-5, err_msg=field)
        np.testing.assert_array_equal(
            np.asarray(restored.placement.chiplet_cell),
            np.asarray(cache.placement.chiplet_cell))
        np.testing.assert_allclose(
            np.asarray(restored.placement.hbm_ij),
            np.asarray(cache.placement.hbm_ij), rtol=0, atol=0)

    @given(design_strategy(), st.integers(0, 2**31 - 1))
    @settings(**_SETTINGS)
    def test_disjoint_chiplet_moves_commute(self, idx, seed):
        """Two relocations of distinct slots to distinct free cells give
        order-independent delta evaluation."""
        v, n_pos, plc, cache, rng = self._setup(idx, seed)
        act = int(n_pos)
        if act < 2:
            return
        s1, s2 = rng.choice(act, size=2, replace=False)
        c1, c2 = self._free_cells(np.asarray(plc.chiplet_cell), act, rng, 2)
        m1 = pm.PlacementMove(kind=jnp.int32(0), slot=jnp.int32(int(s1)),
                              cell=jnp.int32(int(c1)), hbm=jnp.int32(0),
                              anchor=jnp.zeros(2, jnp.float32))
        m2 = pm.PlacementMove(kind=jnp.int32(0), slot=jnp.int32(int(s2)),
                              cell=jnp.int32(int(c2)), hbm=jnp.int32(0),
                              anchor=jnp.zeros(2, jnp.float32))
        ab = self._apply(self._apply(cache, m1, n_pos, v), m2, n_pos, v)
        ba = self._apply(self._apply(cache, m2, n_pos, v), m1, n_pos, v)
        np.testing.assert_array_equal(
            np.asarray(ab.placement.chiplet_cell),
            np.asarray(ba.placement.chiplet_cell))
        for field in pm.NoPStats._fields:
            np.testing.assert_allclose(
                float(getattr(ab.stats, field)),
                float(getattr(ba.stats, field)),
                rtol=1e-5, atol=1e-5, err_msg=field)

    @given(design_strategy(), st.integers(0, 2**31 - 1))
    @settings(**_SETTINGS)
    def test_chiplet_and_hbm_moves_commute(self, idx, seed):
        """A slot relocation and an HBM re-anchor touch disjoint state,
        so their delta evaluations commute."""
        v, n_pos, plc, cache, rng = self._setup(idx, seed)
        act = int(n_pos)
        s = rng.randint(0, act)
        c = int(self._free_cells(np.asarray(plc.chiplet_cell), act, rng, 1)[0])
        mc = pm.PlacementMove(kind=jnp.int32(0), slot=jnp.int32(s),
                              cell=jnp.int32(c), hbm=jnp.int32(0),
                              anchor=jnp.zeros(2, jnp.float32))
        mh = pm.PlacementMove(
            kind=jnp.int32(1), slot=jnp.int32(0), cell=jnp.int32(0),
            hbm=jnp.int32(rng.randint(0, pm.N_HBM)),
            anchor=jnp.asarray(rng.uniform(-1.0, 16.0, 2), jnp.float32))
        ab = self._apply(self._apply(cache, mc, n_pos, v), mh, n_pos, v)
        ba = self._apply(self._apply(cache, mh, n_pos, v), mc, n_pos, v)
        np.testing.assert_array_equal(
            np.asarray(ab.placement.chiplet_cell),
            np.asarray(ba.placement.chiplet_cell))
        np.testing.assert_allclose(np.asarray(ab.placement.hbm_ij),
                                   np.asarray(ba.placement.hbm_ij),
                                   rtol=0, atol=0)
        for field in pm.NoPStats._fields:
            np.testing.assert_allclose(
                float(getattr(ab.stats, field)),
                float(getattr(ba.stats, field)),
                rtol=1e-5, atol=1e-5, err_msg=field)


class TestCompressionProperties:
    @given(st.integers(0, 2**31 - 1), st.integers(4, 512))
    @settings(**_SETTINGS)
    def test_int8_error_bounded_by_scale(self, seed, n):
        g = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        q, scale = comp.quantize_int8(g, jax.random.PRNGKey(seed + 1))
        err = float(jnp.abs(comp.dequantize_int8(q, scale) - g).max())
        assert err <= float(scale) * 1.01 + 1e-9

    @given(st.integers(0, 2**31 - 1))
    @settings(**_SETTINGS)
    def test_error_feedback_identity(self, seed):
        cfg = comp.CompressionConfig(scheme="int8")
        g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64,))}
        e0 = comp.init_error_state(g)
        sent, e1 = comp.compress_grads(g, e0, cfg, jax.random.PRNGKey(1))
        np.testing.assert_allclose(np.asarray(sent["w"] + e1["w"]),
                                   np.asarray(g["w"]), atol=1e-5)


class TestDataProperties:
    @given(st.integers(0, 1000), st.integers(0, 7), st.integers(0, 100))
    @settings(**_SETTINGS)
    def test_batch_tokens_in_vocab(self, seed, shard, step):
        cfg = DataConfig(seed=seed, shard=shard, vocab_size=512)
        b = synthetic_batch(cfg, step)
        toks = np.asarray(b["tokens"])
        assert toks.min() >= 0 and toks.max() < 512
        assert b["tokens"].shape == b["labels"].shape

    @given(st.integers(0, 1000))
    @settings(**_SETTINGS)
    def test_determinism(self, step):
        cfg = DataConfig(seed=3)
        a, b = synthetic_batch(cfg, step), synthetic_batch(cfg, step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


class TestHLOParserProperties:
    @given(st.integers(1, 20), st.integers(16, 128))
    @settings(max_examples=8, deadline=None)
    def test_scan_flops_scale_linearly(self, trips, dim):
        dim = (dim // 16) * 16
        a = jax.ShapeDtypeStruct((dim, dim), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ c, None
            y, _ = jax.lax.scan(body, x, None, length=trips)
            return y

        txt = jax.jit(f).lower(a).compile().as_text()
        pc = hlo_lib.program_costs(txt)
        expect = trips * 2 * dim ** 3
        np.testing.assert_allclose(pc.flops, expect, rtol=1e-6)


class TestModelCausality:
    """Causality invariant: changing future tokens must not change past
    logits (catches masking bugs across all attention flavours)."""

    def _logits(self, cfg, tokens):
        from repro.models import layers as L
        from repro.models import model as M
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        enc = (jnp.full((1, 8, cfg.d_model), 0.05, jnp.float32)
               if cfg.is_encdec else None)
        hidden, _ = M.backbone(params, cfg, tokens, enc_frames=enc)
        hidden = L.apply_norm(params["final_norm"], hidden, cfg.norm)
        return np.asarray(M._unembed_chunk(params, cfg, hidden))

    @given(st.integers(0, 10_000))
    @settings(max_examples=5, deadline=None)
    def test_causal_families(self, seed):
        from repro.configs import ARCH_REGISTRY
        for name in ("qwen2-0.5b", "mamba2-130m", "hymba-1.5b",
                     "h2o-danube-3-4b", "deepseek-v2-lite-16b"):
            cfg = ARCH_REGISTRY[name].reduced()
            key = jax.random.PRNGKey(seed)
            toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
            toks2 = toks.at[0, -1].set((toks[0, -1] + 7) % cfg.vocab_size)
            a = self._logits(cfg, toks)
            b = self._logits(cfg, toks2)
            np.testing.assert_allclose(a[0, :-1], b[0, :-1],
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=name)
