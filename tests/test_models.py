"""Per-architecture smoke tests (assignment requirement: reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs)
plus decode-vs-forward consistency and gradient health."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY, SHAPES_BY_NAME, shape_applicable
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import model as M
from repro.training.optim import Adam, apply_updates, global_norm

ARCH_NAMES = sorted(ARCH_REGISTRY)


def _batch(cfg, bsz=2, seq=32, key=jax.random.PRNGKey(7)):
    batch = {
        "tokens": jax.random.randint(key, (bsz, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (bsz, seq), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.full(
            (bsz, cfg.frontend_tokens, cfg.d_model), 0.01, jnp.float32)
    if cfg.is_encdec:
        batch["enc_frames"] = jnp.full((bsz, seq, cfg.d_model), 0.01,
                                       jnp.float32)
    return batch


class TestSmoke:
    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_forward_shapes_no_nans(self, name):
        cfg = ARCH_REGISTRY[name].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = _batch(cfg)
        hidden, aux = M.backbone(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"))
        expect_len = 32 + (cfg.frontend_tokens
                           if cfg.frontend == "vision_patches" else 0)
        assert hidden.shape == (2, expect_len, cfg.d_model)
        assert np.isfinite(np.asarray(hidden)).all()
        assert np.isfinite(float(aux))

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_train_step_no_nans(self, name):
        cfg = ARCH_REGISTRY[name].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = _batch(cfg)
        loss, grads = jax.value_and_grad(M.train_loss)(params, cfg, batch)
        assert np.isfinite(float(loss))
        gn = float(global_norm(grads))
        assert np.isfinite(gn) and gn > 0.0
        # one optimizer step moves the loss
        opt = Adam(learning_rate=1e-2)
        state = opt.init(params)
        updates, state = opt.update(grads, state, params)
        params2 = apply_updates(params, updates)
        loss2 = float(M.train_loss(params2, cfg, batch))
        assert loss2 < float(loss)

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_param_count_matches_config_estimate(self, name):
        cfg = ARCH_REGISTRY[name].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        actual = M.param_count_actual(params)
        estimate = cfg.param_count()
        assert abs(actual - estimate) / estimate < 0.10, (actual, estimate)


class TestDecodeConsistency:
    TOLS = {"default": 5e-3, "moe": 5e-2, "mla": 5e-2}

    @pytest.mark.parametrize("name", ARCH_NAMES)
    def test_prefill_decode_matches_forward(self, name):
        cfg = ARCH_REGISTRY[name].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        bsz, lp, n_new = 2, 16, 4
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (bsz, lp + n_new), 0, cfg.vocab_size)
        enc = (jnp.full((bsz, 8, cfg.d_model), 0.05, jnp.float32)
               if cfg.is_encdec else None)

        hidden, _ = M.backbone(params, cfg, tokens, enc_frames=enc)
        hidden = L.apply_norm(params["final_norm"], hidden, cfg.norm)
        full_logits = M._unembed_chunk(params, cfg, hidden)

        cache = M.init_cache(cfg, bsz, lp + n_new, jnp.float32)
        logits, cache, enc_out = M.prefill(params, cfg, tokens[:, :lp],
                                           cache, enc_frames=enc)
        tol = self.TOLS["moe"] if cfg.n_experts else (
            self.TOLS["mla"] if cfg.attention == "mla" else
            self.TOLS["default"])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, lp - 1]),
                                   atol=tol, rtol=tol)
        for t in range(n_new):
            logits, cache = M.decode_step(params, cfg, tokens[:, lp + t],
                                          lp + t, cache, enc_out=enc_out)
            np.testing.assert_allclose(
                np.asarray(logits), np.asarray(full_logits[:, lp + t]),
                atol=tol, rtol=tol)


class TestLayerPlan:
    def test_hymba_has_global_layers(self):
        cfg = ARCH_REGISTRY["hymba-1.5b"]
        plan = B.layer_plan(cfg)
        windows = [k.window for k in plan]
        assert windows[0] == 0 and windows[-1] == 0      # global
        assert any(w > 0 for w in windows)               # windowed majority

    def test_deepseek_first_layer_dense(self):
        cfg = ARCH_REGISTRY["deepseek-v2-lite-16b"]
        plan = B.layer_plan(cfg)
        assert not plan[0].moe and all(k.moe for k in plan[1:])

    def test_segment_grouping(self):
        cfg = ARCH_REGISTRY["llama3-8b"]
        segs = B.segments(B.layer_plan(cfg))
        assert len(segs) == 1 and segs[0][1] == cfg.n_layers

    def test_shape_applicability(self):
        long = SHAPES_BY_NAME["long_500k"]
        runs = {n: shape_applicable(c, long)[0]
                for n, c in ARCH_REGISTRY.items()}
        assert runs["mamba2-130m"] and runs["hymba-1.5b"] \
            and runs["h2o-danube-3-4b"]
        assert not runs["llama3-8b"] and not runs["qwen3-moe-235b-a22b"]


class TestLossChunking:
    def test_chunked_loss_matches_direct(self):
        cfg = ARCH_REGISTRY["qwen2-0.5b"].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        bsz, seq = 2, 64
        hidden = jax.random.normal(jax.random.PRNGKey(3),
                                   (bsz, seq, cfg.d_model)) * 0.1
        labels = jax.random.randint(jax.random.PRNGKey(4), (bsz, seq), 0,
                                    cfg.vocab_size)
        chunked = float(M.lm_loss(params, cfg, hidden, labels))
        logits = M._unembed_chunk(params, cfg, hidden)
        logp = jax.nn.log_softmax(logits, axis=-1)
        direct = float(-jnp.take_along_axis(
            logp, labels[..., None], axis=-1).mean())
        assert abs(chunked - direct) < 1e-4
