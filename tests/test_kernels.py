"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes and dtypes per the assignment spec; each kernel must be
allclose to its ref.py oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import params as ps
from repro.kernels import chiplet_eval as ce
from repro.kernels import flash_attention as fa
from repro.kernels import ops
from repro.kernels import ref
from repro.kernels import ssd_scan as ssd


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("batch,hq,hkv,q_len,kv_len,d", [
        (1, 4, 4, 128, 128, 64),       # MHA
        (2, 8, 2, 128, 256, 64),       # GQA group=4
        (1, 14, 2, 256, 256, 64),      # qwen2-style GQA
        (1, 4, 4, 256, 128, 32),       # q longer than kv blocks
        (2, 2, 1, 128, 512, 128),      # MQA, head_dim 128
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, batch, hq, hkv, q_len, kv_len, d, dtype):
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(keys[0], (batch, hq, q_len, d), dtype)
        k = jax.random.normal(keys[1], (batch, hkv, kv_len, d), dtype)
        v = jax.random.normal(keys[2], (batch, hkv, kv_len, d), dtype)
        out = fa.flash_attention(q, k, v, causal=True, interpret=True)
        expect = ref.attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            **_tol(dtype))

    def test_non_causal(self):
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(keys[0], (1, 2, 128, 64))
        k = jax.random.normal(keys[1], (1, 2, 256, 64))
        v = jax.random.normal(keys[2], (1, 2, 256, 64))
        out = fa.flash_attention(q, k, v, causal=False, interpret=True)
        expect = ref.attention_reference(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_sliding_window(self):
        keys = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(keys[0], (1, 2, 256, 64))
        k = jax.random.normal(keys[1], (1, 2, 256, 64))
        v = jax.random.normal(keys[2], (1, 2, 256, 64))
        out = fa.flash_attention(q, k, v, causal=True, window=64,
                                 interpret=True)
        expect = ref.attention_reference(q, k, v, causal=True, window=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_block_size_independence(self):
        keys = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(keys[0], (1, 2, 256, 64))
        k = jax.random.normal(keys[1], (1, 2, 256, 64))
        v = jax.random.normal(keys[2], (1, 2, 256, 64))
        a = fa.flash_attention(q, k, v, block_q=64, block_k=64,
                               interpret=True)
        b = fa.flash_attention(q, k, v, block_q=128, block_k=256,
                               interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("bh,seq,p,n,chunk", [
        (2, 128, 64, 64, 32),
        (4, 256, 64, 128, 64),
        (1, 512, 128, 64, 128),
        (3, 128, 32, 16, 128),        # chunk == seq (single chunk)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_sequential_oracle(self, bh, seq, p, n, chunk, dtype):
        keys = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(keys[0], (bh, seq, p), dtype)
        dt = jax.nn.softplus(
            jax.random.normal(keys[1], (bh, seq))).astype(jnp.float32) * 0.1
        a = -jnp.exp(jax.random.normal(keys[2], (bh,))).astype(jnp.float32)
        b = jax.random.normal(keys[3], (bh, seq, n), dtype) * 0.5
        c = jax.random.normal(keys[0], (bh, seq, n), dtype) * 0.5
        out = ssd.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
        expect = ref.ssd_reference(x, dt, a, b, c)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
            atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_chunked_jnp_matches_oracle(self):
        keys = jax.random.split(jax.random.PRNGKey(1), 4)
        bh, seq, p, n = 2, 256, 64, 64
        x = jax.random.normal(keys[0], (bh, seq, p))
        dt = jax.nn.softplus(jax.random.normal(keys[1], (bh, seq))) * 0.1
        a = -jnp.exp(jax.random.normal(keys[2], (bh,)))
        b = jax.random.normal(keys[3], (bh, seq, n)) * 0.5
        c = jax.random.normal(keys[0], (bh, seq, n)) * 0.5
        out = ref.ssd_chunked_jnp(x, dt, a, b, c, chunk=64)
        expect = ref.ssd_reference(x, dt, a, b, c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_decode_step_matches_scan(self):
        """Sequential decode steps must reproduce the full-sequence scan."""
        keys = jax.random.split(jax.random.PRNGKey(2), 4)
        bh, seq, p, n = 2, 16, 8, 4
        x = jax.random.normal(keys[0], (bh, seq, p))
        dt = jax.nn.softplus(jax.random.normal(keys[1], (bh, seq))) * 0.1
        a = -jnp.exp(jax.random.normal(keys[2], (bh,)))
        b = jax.random.normal(keys[3], (bh, seq, n)) * 0.5
        c = jax.random.normal(keys[0], (bh, seq, n)) * 0.5
        full = ref.ssd_reference(x, dt, a, b, c)
        h = jnp.zeros((bh, n, p))
        ys = []
        for t in range(seq):
            h, y = ref.ssd_decode_step(h, x[:, t], dt[:, t], a, b[:, t],
                                       c[:, t])
            ys.append(y)
        stepped = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                                   rtol=1e-4, atol=1e-4)


class TestChipletEval:
    @pytest.mark.parametrize("n", [256, 512, 1024])
    def test_matches_costmodel(self, n):
        dp = ps.random_design(jax.random.PRNGKey(n), (n,))
        padded = ce.pad_designs(dp)
        cells = ce.pad_cells(dp)
        wl = cm.GENERIC_WORKLOAD
        wl_vals = (float(wl.gemm_ops), float(wl.nongemm_ops),
                   float(wl.hbm_bytes), float(wl.mapping_eff))
        w_vals = (1.0, 1.0, 0.1)
        out = ce.evaluate_batch(padded, cells, wl_vals, w_vals,
                                interpret=True)[:n]
        expect = ref.chiplet_eval_reference(ps.to_flat(dp), wl_vals, w_vals)
        assert out.shape == (n, ce.N_OUT)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_ops_dispatch_consistency(self):
        dp = ps.random_design(jax.random.PRNGKey(7), (256,))
        a = ops.chiplet_eval(dp, backend="pallas")
        b = ops.chiplet_eval(dp, backend="ref")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n", [256, 512])
    def test_explicit_placement_matches_oracle(self, n):
        """Randomly perturbed placements: kernel == jnp oracle on all 12
        metric columns (the extended pairwise-NoP set)."""
        from repro.core import placement as pm
        key = jax.random.PRNGKey(n + 1)
        k_dp, k_cell, k_hbm = jax.random.split(key, 3)
        dp = ps.random_design(k_dp, (n,))
        v = ps.decode(dp)
        m, mesh_n = cm.mesh_dims(cm.footprint_positions(v))
        base = pm.canonical(m, mesh_n, v.hbm_mask, v.arch_type)
        # jitter: random cells for a few slots + random fractional anchors
        cells = jax.random.randint(k_cell, (n, pm.MAX_SLOTS), 0, pm.N_CELLS)
        mix = jax.random.bernoulli(k_cell, 0.3, (n, pm.MAX_SLOTS))
        cells = jnp.where(mix, cells, base.chiplet_cell)
        hbm = base.hbm_ij + jax.random.uniform(
            k_hbm, base.hbm_ij.shape, minval=-1.5, maxval=1.5)
        plc = pm.Placement(chiplet_cell=cells.astype(jnp.int32),
                           hbm_ij=hbm.astype(jnp.float32))
        wl_vals = (1e9, 2e7, 25e6, 0.85)
        w_vals = (1.0, 1.0, 0.1)
        out = ce.evaluate_batch(ce.pad_designs(dp, plc),
                                ce.pad_cells(dp, plc),
                                wl_vals, w_vals, interpret=True)[:n]
        expect = ref.chiplet_eval_reference(ps.to_flat(dp), wl_vals, w_vals,
                                            placement_flat=pm.to_flat(plc))
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_placement_ops_dispatch(self):
        from repro.core import placement as pm
        dp = ps.random_design(jax.random.PRNGKey(9), (256,))
        v = ps.decode(dp)
        m, n = cm.mesh_dims(cm.footprint_positions(v))
        plc = pm.canonical(m, n, v.hbm_mask, v.arch_type)
        a = ops.chiplet_eval(dp, backend="pallas", placement=plc)
        b = ops.chiplet_eval(dp, backend="ref", placement=plc)
        c = ops.chiplet_eval(dp, backend="ref")      # canonical == explicit
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(b), np.asarray(c),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n", [256, 512])
    def test_fast_tier_matches_oracle(self, n):
        """nop_fidelity='fast': the kernel derives the canonical floorplan
        analytically (no cells input, no baseline columns) and must match
        the jnp fast tier AND the full-tier kernel on all 12 columns."""
        dp = ps.random_design(jax.random.PRNGKey(n + 3), (n,))
        wl_vals = (1e9, 2e7, 25e6, 0.85)
        w_vals = (1.0, 1.0, 0.1)
        padded = ce.pad_designs(dp, nop_fidelity="fast")
        out = ce.evaluate_batch(padded, None, wl_vals, w_vals,
                                interpret=True, nop_fidelity="fast")[:n]
        expect = ref.chiplet_eval_reference(ps.to_flat(dp), wl_vals, w_vals,
                                            nop_fidelity="fast")
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)
        full = ce.evaluate_batch(ce.pad_designs(dp), ce.pad_cells(dp),
                                 wl_vals, w_vals, interpret=True)[:n]
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   rtol=1e-4, atol=1e-4)

    def test_fast_tier_ops_dispatch(self):
        """ops.chiplet_eval fidelity threading: fast == full == default
        across both backends on canonical floorplans."""
        dp = ps.random_design(jax.random.PRNGKey(13), (256,))
        a = ops.chiplet_eval(dp, backend="pallas")            # auto -> fast
        b = ops.chiplet_eval(dp, backend="pallas", nop_fidelity="full")
        c = ops.chiplet_eval(dp, backend="ref", nop_fidelity="fast")
        d = ops.chiplet_eval(dp, backend="ref", nop_fidelity="full")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(c), np.asarray(d),
                                   rtol=1e-4, atol=1e-4)
        from repro.core import placement as pm
        vv = ps.decode(dp)
        m, n = cm.mesh_dims(cm.footprint_positions(vv))
        plc = pm.canonical(m, n, vv.hbm_mask, vv.arch_type)
        with pytest.raises(ValueError, match="fast"):
            ops.chiplet_eval(dp, backend="ref", placement=plc,
                             nop_fidelity="fast")

    def test_one_hot_gather_boundary_cells(self):
        """ISSUE-7 tentpole (c): the MXU one-hot anchor gather splits the
        256-cell grid into two 128-lane halves and gathers per-slot
        distances with two dot_generals. The risky inputs are exactly the
        half seams and extremes — cells 0, 127 (last lane of half 0),
        128 (first lane of half 1), 255 — plus duplicated cells (several
        slots in one cell must each gather the full field value, not a
        share of it). Kernel == jnp oracle on all columns."""
        from repro.core import placement as pm
        n = 256
        dp = ps.random_design(jax.random.PRNGKey(31), (n,))
        v = ps.decode(dp)
        m, mesh_n = cm.mesh_dims(cm.footprint_positions(v))
        base = pm.canonical(m, mesh_n, v.hbm_mask, v.arch_type)
        cells = np.asarray(base.chiplet_cell).copy()
        seam = [0, 127, 128, 255]
        for r in range(n):
            k = len(seam)
            # rotate the seam cells through the first 2k slots, with each
            # seam cell duplicated across two slots
            cells[r, : 2 * k] = np.asarray(seam + seam, np.int32)[
                np.arange(2 * k) % (2 * k)]
            cells[r] = np.roll(cells[r], r % pm.MAX_SLOTS)
        hbm = base.hbm_ij + jax.random.uniform(
            jax.random.PRNGKey(32), base.hbm_ij.shape, minval=-1.5,
            maxval=1.5)
        plc = pm.Placement(chiplet_cell=jnp.asarray(cells, jnp.int32),
                           hbm_ij=hbm.astype(jnp.float32))
        wl_vals = (1e9, 2e7, 25e6, 0.85)
        w_vals = (1.0, 1.0, 0.1)
        out = ce.evaluate_batch(ce.pad_designs(dp, plc),
                                ce.pad_cells(dp, plc),
                                wl_vals, w_vals, interpret=True)[:n]
        expect = ref.chiplet_eval_reference(ps.to_flat(dp), wl_vals, w_vals,
                                            placement_flat=pm.to_flat(plc))
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)

    def test_paper_case_design(self):
        """Kernel reproduces the Table-6 case-(i) reward."""
        import sys
        sys.path.insert(0, "tests")
        from test_costmodel import case_i_design
        dp = jax.tree_util.tree_map(
            lambda x: jnp.tile(x[None], (256,)), case_i_design())
        out = ops.chiplet_eval(dp, backend="pallas")
        expect = float(cm.evaluate(case_i_design()).reward)
        np.testing.assert_allclose(np.asarray(out[:, 0]), expect, rtol=1e-4)


class TestDecodeAttention:
    @pytest.mark.parametrize("b,hq,kv,s,d,pos", [
        (2, 8, 2, 512, 64, 100),       # GQA group=4, partially filled
        (1, 32, 8, 1024, 128, 1023),   # llama3-like, full cache
        (4, 4, 4, 512, 64, 0),         # MHA, first token
        (1, 14, 2, 512, 64, 300),      # qwen2-like
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, b, hq, kv, s, d, pos, dtype):
        from repro.kernels import decode_attention as da
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(keys[0], (b, hq, d), dtype)
        k = jax.random.normal(keys[1], (b, kv, s, d), dtype)
        v = jax.random.normal(keys[2], (b, kv, s, d), dtype)
        out = da.decode_attention(q, k, v, jnp.int32(pos), interpret=True)
        expect = ref.decode_attention_reference(q, k, v, jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            **_tol(dtype))

    def test_sliding_window(self):
        from repro.kernels import decode_attention as da
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(keys[0], (1, 4, 64))
        k = jax.random.normal(keys[1], (1, 2, 512, 64))
        v = jax.random.normal(keys[2], (1, 2, 512, 64))
        out = da.decode_attention(q, k, v, jnp.int32(400), window=128,
                                  interpret=True)
        expect = ref.decode_attention_reference(q, k, v, jnp.int32(400),
                                                window=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5)

    def test_block_size_independence(self):
        from repro.kernels import decode_attention as da
        keys = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(keys[0], (2, 8, 64))
        k = jax.random.normal(keys[1], (2, 2, 1024, 64))
        v = jax.random.normal(keys[2], (2, 2, 1024, 64))
        a = da.decode_attention(q, k, v, jnp.int32(700), block_s=128,
                                interpret=True)
        b = da.decode_attention(q, k, v, jnp.int32(700), block_s=1024,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)

    def test_ops_dispatch(self):
        keys = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(keys[0], (1, 4, 64))
        k = jax.random.normal(keys[1], (1, 2, 512, 64))
        v = jax.random.normal(keys[2], (1, 2, 512, 64))
        a = ops.decode_attention(q, k, v, jnp.int32(99), backend="pallas")
        b = ops.decode_attention(q, k, v, jnp.int32(99), backend="ref")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


class TestSurrogateScore:
    """Fused surrogate scoring kernel vs its jnp twin (model.score_folded)."""

    @staticmethod
    def _folded(seed=0):
        from repro.core import env as chipenv
        from repro.surrogate import model as sm
        params = sm.init_params(jax.random.PRNGKey(seed))
        # non-trivial target normalizers, like after training
        params["mu"] = jnp.linspace(-2.0, 4.0, sm.N_TARGETS)
        params["sd"] = jnp.linspace(0.5, 3.0, sm.N_TARGETS)
        return sm.fold_scenario(params, chipenv.EnvConfig().scenario())

    @pytest.mark.parametrize("n", [256, 1024, 1000])
    def test_matches_model_twin(self, n):
        from repro.kernels import surrogate_score as ss
        from repro.surrogate import model as sm
        folded = self._folded()
        flat = ps.to_flat(ps.random_design(jax.random.PRNGKey(n), (n,)))
        out = ss.surrogate_score(flat, folded, interpret=True)
        expect = sm.score_folded(folded, flat)
        assert out.shape == (n,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_block_size_independence(self):
        from repro.kernels import surrogate_score as ss
        folded = self._folded(1)
        flat = ps.to_flat(ps.random_design(jax.random.PRNGKey(5), (512,)))
        a = ss.surrogate_score(flat, folded, interpret=True, block_n=128)
        b = ss.surrogate_score(flat, folded, interpret=True, block_n=512)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)

    def test_ops_dispatch(self):
        folded = self._folded(2)
        flat = ps.to_flat(ps.random_design(jax.random.PRNGKey(6), (300,)))
        a = ops.surrogate_score(flat, folded, backend="pallas")
        b = ops.surrogate_score(flat, folded, backend="ref")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_ranking_agreement(self):
        """The kernel must preserve the jnp twin's top-k set exactly on a
        well-separated pool (the ranker consumes indices, not scores)."""
        from repro.kernels import surrogate_score as ss
        from repro.surrogate import model as sm
        folded = self._folded(3)
        flat = ps.to_flat(ps.random_design(jax.random.PRNGKey(7), (2048,)))
        k_scores = np.asarray(ss.surrogate_score(flat, folded,
                                                 interpret=True))
        j_scores = np.asarray(sm.score_folded(folded, flat))
        top_k = set(np.argsort(k_scores)[::-1][:64].tolist())
        top_j = set(np.argsort(j_scores)[::-1][:64].tolist())
        assert len(top_k & top_j) >= 63   # ties at the boundary only
