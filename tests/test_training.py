"""Training-substrate tests: optimizer, checkpointing, compression,
fault tolerance, data pipeline determinism."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY
from repro.data.pipeline import DataConfig, DataLoader, synthetic_batch
from repro.training import compression as comp
from repro.training import fault
from repro.training import trainer as T
from repro.training.checkpoint import CheckpointManager
from repro.training.optim import (Adam, apply_updates, clip_by_global_norm,
                                  cosine_schedule, global_norm)

ARCH = ARCH_REGISTRY["qwen2-0.5b"].reduced()


def small_cfg(**kw):
    defaults = dict(total_steps=100, warmup_steps=5, checkpoint_every=2,
                    param_dtype=jnp.float32)
    defaults.update(kw)
    return T.TrainConfig(**defaults)


def data_iter(vocab, start=0):
    dl = DataLoader(DataConfig(batch_size=4, seq_len=32, vocab_size=vocab))
    dl.step = start
    return dl


class TestOptim:
    def test_adam_reduces_quadratic(self):
        params = {"x": jnp.array([5.0, -3.0])}
        opt = Adam(learning_rate=0.1)
        state = opt.init(params)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        assert float(jnp.abs(params["x"]).max()) < 0.1

    def test_weight_decay_shrinks(self):
        params = {"x": jnp.ones((4,))}
        opt = Adam(learning_rate=0.01, weight_decay=0.5)
        state = opt.init(params)
        grads = {"x": jnp.zeros((4,))}
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
        assert (np.asarray(params["x"]) < 1.0).all()

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped = clip_by_global_norm(tree, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, 10, 100, min_frac=0.1)
        assert float(lr(0)) == 0.0
        assert abs(float(lr(10)) - 1.0) < 1e-6
        assert abs(float(lr(100)) - 0.1) < 1e-2
        assert float(lr(55)) < float(lr(10))


class TestCheckpoint:
    def test_roundtrip(self):
        cfg = small_cfg()
        state = T.init_state(ARCH, cfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            mgr.save(1, state)
            restored, step = mgr.restore(state)
            assert step == 1
            for a, b in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_last_k(self):
        cfg = small_cfg()
        state = T.init_state(ARCH, cfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            for s in (1, 2, 3, 4):
                mgr.save(s, state)
            files = [f for f in os.listdir(d) if f.endswith(".npz")]
            assert len(files) == 2
            assert mgr.latest_step() == 4
            with pytest.raises(FileNotFoundError):
                mgr.restore(state, step=1)

    def test_verify_detects_missing(self):
        cfg = small_cfg()
        state = T.init_state(ARCH, cfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            path = mgr.save(1, state)
            assert mgr.verify()
            os.remove(path)
            assert not mgr.verify()


class TestCompression:
    def test_int8_roundtrip_accuracy(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        q, scale = comp.quantize_int8(g, jax.random.PRNGKey(1))
        deq = comp.dequantize_int8(q, scale)
        assert float(jnp.abs(deq - g).max()) <= float(scale) * 1.01

    def test_error_feedback_preserves_sum(self):
        """Residual + transmitted == original (error feedback invariant)."""
        cfg = comp.CompressionConfig(scheme="int8")
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,))}
        err = comp.init_error_state(grads)
        sent, new_err = comp.compress_grads(grads, err, cfg,
                                            jax.random.PRNGKey(1))
        recon = sent["w"] + new_err["w"]
        np.testing.assert_allclose(np.asarray(recon),
                                   np.asarray(grads["w"]), atol=1e-5)

    def test_topk_keeps_largest(self):
        cfg = comp.CompressionConfig(scheme="topk", topk_frac=0.1)
        g = jnp.arange(100.0)
        grads, err = comp.compress_grads(
            {"w": g}, comp.init_error_state({"w": g}), cfg,
            jax.random.PRNGKey(0))
        nz = np.nonzero(np.asarray(grads["w"]))[0]
        assert len(nz) == 10
        assert nz.min() == 90

    def test_compressed_training_still_learns(self):
        cfg = small_cfg(compression=comp.CompressionConfig(scheme="int8"))
        state = T.init_state(ARCH, cfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(T.make_train_step(ARCH, cfg))
        it = data_iter(ARCH.vocab_size)
        losses = []
        batch = next(it)
        for _ in range(8):
            state, m = step_fn(state, batch)   # same batch -> must overfit
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_ratio(self):
        assert comp.compression_ratio(
            comp.CompressionConfig(scheme="int8")) == 0.25
        assert comp.compression_ratio(
            comp.CompressionConfig(scheme="none")) == 1.0


class TestFaultTolerance:
    def test_recovers_from_crashes(self):
        cfg = small_cfg(checkpoint_every=2)
        injector = fault.FailureInjector({3: "crash", 7: "crash"})
        with tempfile.TemporaryDirectory() as d:
            state, history, restarts = fault.run_with_restarts(
                ARCH, cfg, lambda start: data_iter(ARCH.vocab_size, start),
                d, total_steps=10, injector=injector)
        assert restarts == 2
        assert int(np.asarray(state["step"])) == 10
        # steps 3,4 replayed after crash-at-3 (ckpt at 2) etc.
        assert len(history) >= 10

    def test_too_many_failures_raises(self):
        cfg = small_cfg(checkpoint_every=100)   # never checkpoints early
        injector = fault.FailureInjector({0: "crash", 1: "crash"})
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(RuntimeError):
                fault.run_with_restarts(
                    ARCH, cfg,
                    lambda start: data_iter(ARCH.vocab_size, start),
                    d, total_steps=5, injector=injector, max_restarts=1)


class TestDataPipeline:
    def test_deterministic(self):
        cfg = DataConfig(seed=7, shard=2)
        a = synthetic_batch(cfg, 5)
        b = synthetic_batch(cfg, 5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_shards_disjoint(self):
        a = synthetic_batch(DataConfig(shard=0), 0)
        b = synthetic_batch(DataConfig(shard=1), 0)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_labels_shifted(self):
        cfg = DataConfig()
        batch = synthetic_batch(cfg, 0)
        assert batch["tokens"].shape == (cfg.batch_size, cfg.seq_len)
        assert batch["labels"].shape == (cfg.batch_size, cfg.seq_len)
        # loss mask zeroes EOS targets
        eos_positions = np.asarray(batch["labels"]) == 2
        assert (np.asarray(batch["loss_mask"])[eos_positions] == 0).all()

    def test_loader_state_restore(self):
        dl = DataLoader(DataConfig())
        next(dl), next(dl)
        st = dl.state()
        b3 = next(dl)
        dl2 = DataLoader(DataConfig())
        dl2.restore(st)
        b3b = next(dl2)
        np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                      np.asarray(b3b["tokens"]))
