"""Placement subsystem tests (core/placement.py + the threaded layers).

Covers the ISSUE-2 acceptance criteria:
  - brute-force numpy oracle for the pairwise hop model on small m x n
    grids, and exact agreement of the canonical placement with the legacy
    ``hbm_worst_hops`` / ``m + n - 2`` scan,
  - canonical-placement regression: evaluate() matches the recorded
    pre-refactor latency/reward values to 1e-5 on a random design batch,
  - mutation semantics (relocate/swap, HBM re-anchor),
  - placement SA refinement never worse than canonical (single + batched
    over scenarios),
  - the placement-extended env/PPO action space.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import workload as wl
from repro.rl import ppo
from repro.sa import annealing as sa

_HERE = os.path.dirname(os.path.abspath(__file__))


def _canonical_for(dp: ps.DesignPoint):
    v = ps.decode(dp)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    return pm.canonical(m, n, v.hbm_mask, v.arch_type), n_pos, m, n, v


class TestBruteForceOracle:
    """Enumerate small grids in numpy; the vectorized model must match."""

    @staticmethod
    def _numpy_nop(cells, n_pos, hbm_ij, mask, arch):
        """Straight-line python/numpy re-derivation of nop_stats."""
        occ = [(c // pm.GRID, c % pm.GRID) for c in cells[:n_pos]]
        i_min = min(i for i, _ in occ)
        i_max = max(i for i, _ in occ)
        j_min = min(j for _, j in occ)
        j_max = max(j for _, j in occ)

        def dmin(i, j):
            best = 1e9
            for b in range(6):
                if mask >> b & 1:
                    d = abs(i - hbm_ij[b][0]) + abs(j - hbm_ij[b][1])
                    floor = 0.0 if (b == 5 and arch >= 1) else 1.0
                    best = min(best, max(d, floor))
            return best

        box = [(i, j) for i in range(i_min, i_max + 1)
               for j in range(j_min, j_max + 1)]
        worst_hbm = max(dmin(i, j) for i, j in box)
        mean_hbm = sum(dmin(i, j) for i, j in occ) / n_pos
        ci = sum(i for i, _ in occ) / n_pos
        cj = sum(j for _, j in occ) / n_pos
        d_cent = [abs(i - ci) + abs(j - cj) for i, j in occ]
        mean_ai = sum(d_cent) / n_pos
        worst_ai = (i_max - i_min) + (j_max - j_min)
        bm, bn = i_max - i_min + 1, j_max - j_min + 1
        edges = max(bm * (bn - 1) + bn * (bm - 1), 1)
        cont = (4 * sum(dmin(i, j) for i, j in occ) + sum(d_cent)) / edges
        return worst_ai, mean_ai, worst_hbm, mean_hbm, cont

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_small_grids(self, seed):
        rng = np.random.RandomState(seed)
        for _ in range(20):
            n_pos = rng.randint(1, 13)
            cells = rng.choice(36, size=n_pos, replace=False)      # 6x6 area
            cells = np.concatenate([
                (cells // 6) * pm.GRID + cells % 6,
                rng.randint(0, pm.N_CELLS, pm.MAX_SLOTS - n_pos)])
            mask = rng.randint(1, 64)
            arch = rng.randint(0, 3)
            hbm_ij = rng.uniform(-1, 7, (6, 2)).round(1)
            plc = pm.Placement(chiplet_cell=jnp.asarray(cells, jnp.int32),
                               hbm_ij=jnp.asarray(hbm_ij, jnp.float32))
            stats = pm.nop_stats(plc, jnp.float32(n_pos), jnp.int32(mask),
                                 jnp.float32(arch))
            expect = self._numpy_nop(cells, n_pos, hbm_ij, mask, arch)
            got = (float(stats.hops_ai_worst), float(stats.hops_ai_mean),
                   float(stats.hops_hbm_worst), float(stats.hops_hbm_mean),
                   float(stats.link_contention))
            np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)

    def test_canonical_reproduces_legacy_worst_hops(self):
        """For EVERY footprint count and HBM mask, the canonical placement's
        pairwise reduction equals the legacy Fig.-4 grid scan."""
        for arch in (0, 2):
            p = jnp.arange(1, 129, dtype=jnp.int32)
            m, n = cm.mesh_dims(p)
            for mask in range(1, 64, 5):
                mask_a = jnp.full_like(p, mask)
                arch_a = jnp.full(p.shape, float(arch), jnp.float32)
                plc = pm.canonical(m, n, mask_a, arch_a)
                stats = pm.nop_stats(plc, p.astype(jnp.float32), mask_a,
                                     arch_a)
                legacy = cm.hbm_worst_hops(m, n, mask_a, arch_a)
                np.testing.assert_allclose(np.asarray(stats.hops_hbm_worst),
                                           np.asarray(legacy), rtol=0,
                                           atol=0, err_msg=f"mask={mask}")
                np.testing.assert_allclose(np.asarray(stats.hops_ai_worst),
                                           np.asarray(m + n - 2.0),
                                           rtol=0, atol=0)


class TestCanonicalRegression:
    """evaluate() under canonical placement == pre-refactor values."""

    def test_matches_recorded_prerefactor_metrics(self):
        with open(os.path.join(_HERE,
                               "data_placement_regression.json")) as f:
            ref = json.load(f)
        dp = ps.random_design(jax.random.PRNGKey(ref["seed"]),
                              (ref["batch"],))
        m = cm.evaluate(dp)
        for field in ("reward", "lat_hbm_ai_ns", "lat_ai_ai_ns",
                      "hops_hbm_ai", "hops_ai_ai"):
            np.testing.assert_allclose(
                np.asarray(getattr(m, field), np.float64),
                np.asarray(ref[field]), rtol=1e-5, atol=1e-5,
                err_msg=field)

    def test_congestion_and_hop_ratios_are_one_at_canonical(self):
        dp = ps.random_design(jax.random.PRNGKey(7), (128,))
        m = cm.evaluate(dp)
        np.testing.assert_array_equal(np.asarray(m.nop_congestion), 1.0)

    def test_explicit_canonical_equals_default(self):
        dp = ps.random_design(jax.random.PRNGKey(11), (32,))
        plc, _, _, _, _ = _canonical_for(dp)
        a = cm.evaluate(dp)
        b = cm.evaluate(dp, placement=plc)
        np.testing.assert_allclose(np.asarray(a.reward),
                                   np.asarray(b.reward), rtol=1e-6)


class TestMutations:
    def test_relocate_swaps_occupant(self):
        dp = ps.random_design(jax.random.PRNGKey(0))
        plc, n_pos, _, _, _ = _canonical_for(dp)
        cells0 = np.asarray(plc.chiplet_cell)
        # move slot 0 onto slot 1's cell -> they must swap
        out = pm.relocate_chiplet(plc, 0, int(cells0[1]), n_pos)
        cells1 = np.asarray(out.chiplet_cell)
        assert cells1[0] == cells0[1]
        assert cells1[1] == cells0[0]
        # no duplicate cells among active slots
        act = int(n_pos)
        assert len(set(cells1[:act])) == act

    def test_relocate_to_free_cell(self):
        dp = ps.random_design(jax.random.PRNGKey(1))
        plc, n_pos, _, _, _ = _canonical_for(dp)
        free = 15 * pm.GRID + 15          # corner cell, never canonical
        out = pm.relocate_chiplet(plc, 0, free, n_pos)
        cells = np.asarray(out.chiplet_cell)
        assert cells[0] == free
        act = int(n_pos)
        assert len(set(cells[:act])) == act

    def test_move_hbm(self):
        dp = ps.random_design(jax.random.PRNGKey(2))
        plc, _, _, _, _ = _canonical_for(dp)
        out = pm.move_hbm(plc, 3, 2 * pm.GRID + 5)
        np.testing.assert_allclose(np.asarray(out.hbm_ij)[3], [2.0, 5.0])

    def test_flat_roundtrip(self):
        dp = ps.random_design(jax.random.PRNGKey(3), (4,))
        plc, _, _, _, _ = _canonical_for(dp)
        back = pm.from_flat(pm.to_flat(plc))
        np.testing.assert_array_equal(np.asarray(back.chiplet_cell),
                                      np.asarray(plc.chiplet_cell))
        np.testing.assert_allclose(np.asarray(back.hbm_ij),
                                   np.asarray(plc.hbm_ij))


class TestPlacementSA:
    def test_never_worse_than_canonical(self):
        dp = ps.random_design(jax.random.PRNGKey(4))
        res = sa.refine_placement(jax.random.PRNGKey(5), dp,
                                  chipenv.EnvConfig(),
                                  sa.PlacementSAConfig(n_iters=300))
        assert float(res.best_reward) >= float(res.canonical_reward)

    def test_scenario_batched(self):
        scen = cm.stack_scenarios([
            cm.Scenario(workload=wl.MLPERF[n]) for n in ("resnet50", "bert")])
        dps = ps.random_design(jax.random.PRNGKey(6), (2,))
        res = sa.refine_placement_scenarios(
            jax.random.PRNGKey(7), dps, scen, chipenv.EnvConfig(),
            sa.PlacementSAConfig(n_iters=200))
        assert res.best_reward.shape == (2,)
        assert (np.asarray(res.best_reward)
                >= np.asarray(res.canonical_reward)).all()

    def test_congestion_channel_moves_reward(self):
        """A deliberately bad placement must score below canonical (the
        congestion + per-hop-energy channels are live, not cosmetic)."""
        dp = ps.random_design(jax.random.PRNGKey(8), (64,))
        plc, n_pos, m, n, v = _canonical_for(dp)
        # sprawl: push slot 0 of every design to the far grid corner
        cells = jnp.asarray(plc.chiplet_cell)
        cells = cells.at[:, 0].set(pm.N_CELLS - 1)
        bad = plc._replace(chiplet_cell=cells)
        a = cm.evaluate(dp)
        b = cm.evaluate(dp, placement=bad)
        # multi-chiplet designs spread traffic over a 16x16 bounding box:
        # strictly more hops -> reward strictly drops for most designs
        multi = np.asarray(n_pos) > 2
        assert (np.asarray(b.reward)[multi]
                <= np.asarray(a.reward)[multi] + 1e-5).all()
        assert (np.asarray(b.reward)[multi]
                < np.asarray(a.reward)[multi] - 1e-4).any()


class TestFastTier:
    """Two-tier NoP dispatch: fast (closed-form canonical) vs full
    (pairwise) — the ISSUE-3 tentpole parity criteria."""

    def test_nop_stats_fast_equals_full_on_canonical(self):
        """nop_stats_fast == nop_stats(canonical(...)) on every field,
        for every footprint count / a sweep of HBM masks / all archs."""
        for arch in (0, 1, 2):
            p = jnp.arange(1, 129, dtype=jnp.int32)
            m, n = cm.mesh_dims(p)
            for mask in range(1, 64, 7):
                mask_a = jnp.full_like(p, mask)
                arch_a = jnp.full(p.shape, float(arch), jnp.float32)
                plc = pm.canonical(m, n, mask_a, arch_a)
                full = pm.nop_stats(plc, p.astype(jnp.float32), mask_a,
                                    arch_a)
                fast = pm.nop_stats_fast(m, n, p.astype(jnp.float32),
                                         mask_a, arch_a)
                for field in pm.NoPStats._fields:
                    np.testing.assert_allclose(
                        np.asarray(getattr(fast, field)),
                        np.asarray(getattr(full, field)),
                        rtol=1e-5, atol=1e-5,
                        err_msg=f"{field} mask={mask} arch={arch}")

    def test_evaluate_fidelity_tiers_agree(self):
        """evaluate(auto) == evaluate(full) == evaluate(fast) on a random
        design batch (canonical floorplan), allclose 1e-5."""
        dp = ps.random_design(jax.random.PRNGKey(21), (256,))
        auto = cm.evaluate(dp)
        full = cm.evaluate(dp, nop_fidelity="full")
        fast = cm.evaluate(dp, nop_fidelity="fast")
        for field in ("reward", "lat_hbm_ai_ns", "lat_ai_ai_ns",
                      "hops_hbm_ai", "hops_ai_ai", "hops_hbm_mean",
                      "hops_ai_mean", "link_contention", "eff_tops",
                      "pkg_cost", "energy_per_task_j"):
            a = np.asarray(getattr(auto, field), np.float64)
            np.testing.assert_allclose(a, np.asarray(getattr(full, field),
                                                     np.float64),
                                       rtol=1e-5, atol=1e-5, err_msg=field)
            np.testing.assert_array_equal(a, np.asarray(getattr(fast, field),
                                                        np.float64),
                                          err_msg=field)

    def test_fast_rejects_explicit_placement(self):
        dp = ps.random_design(jax.random.PRNGKey(22))
        plc, _, _, _, _ = _canonical_for(dp)
        with pytest.raises(ValueError, match="fast"):
            cm.evaluate(dp, placement=plc, nop_fidelity="fast")
        with pytest.raises(ValueError, match="nop_fidelity"):
            cm.evaluate(dp, nop_fidelity="bogus")

    def test_full_tier_explicit_still_matches_oracle_numbers(self):
        """The full tier's explicit-placement path (now normalized against
        the fast-tier canonical baseline) still scores the canonical
        placement identically to the default path."""
        dp = ps.random_design(jax.random.PRNGKey(23), (64,))
        plc, _, _, _, _ = _canonical_for(dp)
        a = cm.evaluate(dp)
        b = cm.evaluate(dp, placement=plc)
        np.testing.assert_allclose(np.asarray(a.reward), np.asarray(b.reward),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a.nop_congestion),
                                   np.asarray(b.nop_congestion),
                                   rtol=1e-5, atol=1e-5)

    def test_env_threads_fidelity(self):
        """EnvConfig(nop_fidelity=...) reaches costmodel.evaluate: every
        tier produces the same rewards for design-only actions."""
        a = chipenv.action_space.sample(jax.random.PRNGKey(1))
        rs = []
        for fid in ("auto", "fast", "full"):
            cfg = chipenv.EnvConfig(nop_fidelity=fid)
            state, _ = chipenv.reset(jax.random.PRNGKey(0), cfg)
            _, _, r, _, _ = chipenv.step(state, a, cfg)
            rs.append(float(r))
        np.testing.assert_allclose(rs, rs[0], rtol=1e-5)


class TestNoPInvariantsSeeded:
    """Deterministic, hypothesis-free mirror of the TestNoPProperties
    invariants in tests/test_properties.py, so the NoP contracts stay
    enforced on containers without `hypothesis` installed."""

    def test_randomized_invariants(self):
        rng0 = np.random.RandomState(0)
        for _ in range(20):
            n_pos = rng0.randint(1, 129)
            mask = rng0.randint(1, 64)
            arch = rng0.randint(0, 3)
            rng = np.random.RandomState(rng0.randint(0, 2**31 - 1))
            cells = rng.choice(pm.N_CELLS, size=n_pos, replace=False)
            cells = np.concatenate(
                [cells, rng.randint(0, pm.N_CELLS, pm.MAX_SLOTS - n_pos)])
            hbm_ij = rng.uniform(-1.0, 16.0, (pm.N_HBM, 2)).astype(np.float32)
            plc = pm.Placement(chiplet_cell=jnp.asarray(cells, jnp.int32),
                               hbm_ij=jnp.asarray(hbm_ij))
            stats = pm.nop_stats(plc, jnp.float32(n_pos), jnp.int32(mask),
                                 jnp.float32(arch))

            # slot-relabeling permutation invariance
            perm = np.arange(pm.MAX_SLOTS)
            perm[:n_pos] = rng.permutation(n_pos)
            permuted = pm.nop_stats(
                plc._replace(chiplet_cell=plc.chiplet_cell[perm]),
                jnp.float32(n_pos), jnp.int32(mask), jnp.float32(arch))
            for field in pm.NoPStats._fields:
                np.testing.assert_allclose(
                    float(getattr(stats, field)),
                    float(getattr(permuted, field)),
                    rtol=1e-5, atol=1e-5, err_msg=field)

            # hbm_floors respected; worst >= mean; contention >= 0
            floors = np.asarray(pm.hbm_floors(jnp.int32(mask),
                                              jnp.float32(arch)))
            placed = np.asarray(
                [(mask >> b) & 1 for b in range(pm.N_HBM)]) > 0
            min_floor = floors[placed].min()
            assert float(stats.hops_hbm_mean) >= min_floor - 1e-6
            assert float(stats.hops_hbm_worst) >= min_floor - 1e-6
            assert (float(stats.hops_hbm_worst)
                    >= float(stats.hops_hbm_mean) - 1e-5)
            assert (float(stats.hops_ai_worst)
                    >= float(stats.hops_ai_mean) - 1e-5)
            assert float(stats.link_contention) >= 0.0
            assert float(stats.region_edges) >= 0.0


class TestProfileGuidedSA:
    """ISSUE-3 satellite: profile-guided placement SA regression."""

    NAMES = ("resnet50", "bert", "maskrcnn", "3dunet")

    def _run(self, profile_guided):
        from repro.optimizer import scenario as suite
        env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
        scen = cm.stack_scenarios([
            cm.Scenario(workload=wl.MLPERF[n]) for n in self.NAMES])
        dps = ps.random_design(jax.random.PRNGKey(42), (len(self.NAMES),))
        cfg = sa.PlacementSAConfig(n_iters=600, profile_guided=profile_guided,
                                   p_guided=0.5, guide_sigma=1.25,
                                   record_every=20)
        return sa.refine_placement_scenarios(
            jax.random.PRNGKey(7), dps, scen, env_cfg, cfg)

    def test_guided_never_worse_and_converges_no_slower(self):
        """On a fixed seeded scenario batch under the placement-sensitive
        preset, the profile-guided proposer (a) never scores below the
        canonical floorplan, (b) ends at least as high as the uniform
        proposer, and (c) reaches the uniform proposer's final level in
        no more moves than the uniform proposer itself needed."""
        guided = self._run(True)
        uniform = self._run(False)
        g_best = np.asarray(guided.best_reward, np.float64)
        u_best = np.asarray(uniform.best_reward, np.float64)
        canon = np.asarray(guided.canonical_reward, np.float64)
        assert (g_best >= canon - 1e-6).all()
        assert (g_best >= u_best - 1e-6).all()

        gh = np.asarray(guided.history, np.float64)    # (S, n_records)
        uh = np.asarray(uniform.history, np.float64)
        assert gh.shape == uh.shape and gh.shape[0] == len(self.NAMES)
        for s in range(gh.shape[0]):
            target = uh[s, -1] - 1e-6
            reached = gh[s] >= target
            assert reached.any(), f"scenario {s}: guided never reached " \
                                  f"the uniform proposer's final reward"
            t_guided = int(np.argmax(reached))
            t_uniform = int(np.argmax(uh[s] >= target))
            assert t_guided <= t_uniform, (
                f"scenario {s}: guided needed {t_guided} records vs "
                f"uniform's {t_uniform}")

    def test_history_is_monotone_best_so_far(self):
        res = self._run(True)
        h = np.asarray(res.history)
        assert (np.diff(h, axis=-1) >= -1e-6).all()


class TestExtendedEnv:
    def test_ext_action_space_shapes(self):
        cfg = chipenv.EnvConfig(placement_actions=True)
        assert chipenv.action_dim(cfg) == ps.N_EXT_PARAMS
        assert chipenv.obs_dim(cfg) == chipenv.OBS_DIM_PLACEMENT
        a = chipenv.ext_action_space.sample(jax.random.PRNGKey(0))
        assert a.shape == (ps.N_EXT_PARAMS,)
        assert chipenv.ext_action_space.contains(np.asarray(a))
        # the subspace of the 4 mutation heads composes with the design
        # space back to the extended space
        assert (chipenv.placement_action_space.nvec
                == ps.PLACEMENT_HEAD_SIZES)
        pa = chipenv.placement_action_space.sample(jax.random.PRNGKey(1))
        assert chipenv.ext_action_space.contains(
            np.concatenate([np.asarray(chipenv.action_space.sample(
                jax.random.PRNGKey(2))), np.asarray(pa)]))

    def test_step_with_placement_action(self):
        cfg = chipenv.EnvConfig(placement_actions=True)
        state, obs = chipenv.reset(jax.random.PRNGKey(0), cfg)
        assert obs.shape == (chipenv.OBS_DIM_PLACEMENT,)
        a = chipenv.ext_action_space.sample(jax.random.PRNGKey(1))
        _, obs2, r, done, _ = chipenv.step(state, a, cfg)
        assert obs2.shape == (chipenv.OBS_DIM_PLACEMENT,)
        assert np.isfinite(float(r))

    def test_noop_mutation_matches_design_only(self):
        """A placement action that relocates a slot onto its own cell and
        re-anchors an unplaced stack is a reward no-op."""
        cfg = chipenv.EnvConfig(placement_actions=True)
        key = jax.random.PRNGKey(2)
        design_a = chipenv.action_space.sample(key)
        dp = ps.from_flat(design_a)
        plc, n_pos, _, _, v = _canonical_for(dp)
        mask = int(np.asarray(v.hbm_mask))
        unplaced = next(b for b in range(6) if not mask >> b & 1) \
            if mask != 63 else None
        if unplaced is None:
            pytest.skip("all stacks placed for this sample")
        noop = jnp.asarray(
            [0, int(np.asarray(plc.chiplet_cell)[0]), unplaced, 0], jnp.int32)
        state, _ = chipenv.reset(jax.random.PRNGKey(3), cfg)
        _, _, r_ext, _, _ = chipenv.step(
            state, jnp.concatenate([design_a, noop]), cfg)
        expect = cm.reward_only(dp)
        np.testing.assert_allclose(float(r_ext), float(expect), rtol=1e-6)


class TestExtendedPPO:
    def test_train_with_placement_heads(self):
        cfg_env = chipenv.EnvConfig(placement_actions=True)
        cfg = ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32)
        res = ppo.train(jax.random.PRNGKey(0), cfg_env, cfg,
                        total_timesteps=32 * 2 * 2)
        assert res.best_action.shape == (ps.N_EXT_PARAMS,)
        assert np.isfinite(float(res.best_reward))
        flat = np.asarray(ps.to_flat(res.best_design))
        assert chipenv.action_space.contains(flat)

    def test_batched_placement_action_rejected(self):
        cfg = chipenv.EnvConfig(placement_actions=True)
        state, _ = chipenv.reset(jax.random.PRNGKey(0), cfg)
        batch = chipenv.ext_action_space.sample(jax.random.PRNGKey(1), (4,))
        with pytest.raises(ValueError, match="vmap"):
            chipenv.step(state, batch, cfg)

    def test_portfolio_placement_reward_consistent(self):
        """optimize() with placement actions must return placement_reward
        >= best_reward (the RL winner's placement is not discarded)."""
        from repro.optimizer import portfolio
        env_cfg = chipenv.EnvConfig(placement_actions=True)
        cfg = portfolio.PortfolioConfig(
            n_sa=1, n_rl=2, sa=sa.SAConfig(n_iters=200),
            rl=ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32),
            rl_timesteps=32 * 2 * 2, refine=False,
            placement_sa=sa.PlacementSAConfig(n_iters=100))
        res = portfolio.optimize(jax.random.PRNGKey(1), env_cfg, cfg)
        assert res.placement_reward >= res.best_reward - 1e-4
