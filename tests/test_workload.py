"""Workload-descriptor tests: the co-design loop's inputs.

Cross-checks the analytical FLOPs accounting (configs/base.py) against
the *compiled* model (while-aware HLO dot census) — the same numbers feed
both the Chiplet-Gym objective and the roofline's MODEL_FLOPS.
"""

import builtins
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as H
from repro.configs import ARCH_REGISTRY
from repro.core import workload as wl
from repro.models import model as M


class TestMLPerfTable7:
    def test_all_five_present(self):
        assert set(wl.MLPERF) == {"resnet50", "efficientdet", "maskrcnn",
                                  "3dunet", "bert"}

    def test_flops_match_paper(self):
        # Table 7 FLOPs/forward-pass (MACs = FLOPs / 2)
        expect = {"resnet50": 4.0, "efficientdet": 410.0,
                  "maskrcnn": 447.0, "3dunet": 947.0, "bert": 32.0}
        for name, gflops in expect.items():
            w = wl.MLPERF[name]
            assert float(w.gemm_ops) == pytest.approx(gflops * 1e9 / 2)


class TestArchWorkloads:
    def test_decode_streams_active_params(self):
        cfg = ARCH_REGISTRY["llama3-8b"]
        w = wl.from_arch_config(cfg, "decode")
        assert float(w.hbm_bytes) >= 2.0 * cfg.active_param_count()

    def test_moe_uses_active_not_total(self):
        cfg = ARCH_REGISTRY["qwen3-moe-235b-a22b"]
        w = wl.from_arch_config(cfg, "decode")
        # 22B active, not 235B total
        assert float(w.gemm_ops) < 0.2 * cfg.param_count()

    def test_train_is_3x_forward(self):
        cfg = ARCH_REGISTRY["qwen2-0.5b"]
        fwd = wl.from_arch_config(cfg, "prefill")
        train = wl.from_arch_config(cfg, "train")
        np.testing.assert_allclose(float(train.gemm_ops),
                                   3.0 * float(fwd.gemm_ops), rtol=1e-6)

    def test_registry_includes_archs(self):
        reg = wl.registry()
        assert "llama3-8b:train" in reg and "bert" in reg

    def test_registry_tolerates_missing_configs(self, monkeypatch):
        """Bootstrap order: repro.configs absent -> MLPerf-only registry."""
        monkeypatch.delitem(sys.modules, "repro.configs", raising=False)
        monkeypatch.setitem(sys.modules, "repro.configs", None)
        reg = wl.registry()
        assert set(reg) == set(wl.MLPERF)

    def test_registry_surfaces_transitive_import_error(self, monkeypatch):
        """Regression: a failure *inside* repro.configs must not be
        swallowed into a silently-shrunk registry."""
        real_import = builtins.__import__

        def boom(name, *args, **kwargs):
            if name == "repro.configs":
                raise ModuleNotFoundError(
                    "No module named 'some_transitive_dep'",
                    name="some_transitive_dep")
            return real_import(name, *args, **kwargs)

        monkeypatch.delitem(sys.modules, "repro.configs", raising=False)
        monkeypatch.setattr(builtins, "__import__", boom)
        with pytest.raises(ModuleNotFoundError, match="some_transitive_dep"):
            wl.registry()


class TestAnalyticalVsCompiled:
    @pytest.mark.parametrize("name", ["qwen2-0.5b", "llama3-8b"])
    def test_config_flops_vs_hlo(self, name):
        """flops_per_token (analytical) vs compiled forward (HLO census)
        on the reduced config — must agree within 25 % (analytical model
        skips norms/rotary and counts GQA approximately)."""
        cfg = ARCH_REGISTRY[name].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        bsz, seq = 2, 64

        def fwd(params, tokens):
            hidden, _ = M.backbone(params, cfg, tokens)
            return M._unembed_chunk(params, cfg, hidden)

        tokens = jax.ShapeDtypeStruct((bsz, seq), jnp.int32)
        params_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        txt = jax.jit(fwd).lower(params_sds, tokens).compile().as_text()
        hlo_flops = H.program_costs(txt).flops

        analytical = cfg.flops_per_token(seq) * bsz * seq
        assert hlo_flops == pytest.approx(analytical, rel=0.25), \
            (hlo_flops, analytical)
