"""Minimal vendored stand-in for the ``hypothesis`` API this repo uses.

The CI container has no ``hypothesis`` wheel and the build forbids
installing one, which used to leave the property suites permanently
skipped (``pytest.importorskip``). ``tests/conftest.py`` puts this
package on ``sys.path`` ONLY when the real library is missing, so the
property tests execute everywhere; with a real ``hypothesis`` installed
(requirements-dev.txt) it wins and this shim is inert.

Scope: exactly the subset the test-suite imports —
``given``/``settings`` and the ``strategies`` used by
tests/test_properties.py and tests/test_evo.py. Generation is a
seeded-random sweep (first example = all lower bounds, second = all
upper bounds, the rest pseudo-random, seeded per test so failures
reproduce); there is no shrinking and no example database. Failures
report the offending example in the assertion chain.
"""

from __future__ import annotations

import inspect
import random
import zlib

from . import strategies

__all__ = ["given", "settings", "strategies"]
__version__ = "0.0-vendored-shim"


def settings(**kwargs):
    """Record (max_examples, deadline, ...) on the decorated test."""

    def deco(fn):
        fn._shim_settings = kwargs
        return fn

    return deco


def given(*strats, **kw_strats):
    """Run the test once per generated example (no shrinking)."""
    if kw_strats:
        raise NotImplementedError(
            "the vendored hypothesis shim only supports positional "
            "strategies")

    def deco(fn):
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_shim_settings", None) or getattr(
                fn, "_shim_settings", {})
            n = int(conf.get("max_examples", 100))
            # per-test deterministic stream: reruns hit the same examples
            rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                mode = ("low" if i == 0 else "high" if i == 1 else "rand")
                example = tuple(s.example(rnd, mode) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"shim-hypothesis example {i}/{n} failed for "
                        f"{fn.__qualname__}: args={example!r}") from e

        # identity without functools.wraps: copying __wrapped__ would
        # make pytest introspect the inner signature and demand fixtures
        # for the generated parameters. The exposed signature keeps only
        # a leading `self` (for test methods).
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        params = list(inspect.signature(fn).parameters.values())
        keep = params[:1] if params and params[0].name == "self" else []
        wrapper.__signature__ = inspect.Signature(keep)

        # mirror the real library's marker attribute: plugins (anyio's
        # pytest hook, pytest-asyncio) reach for `.hypothesis.inner_test`
        class _Marker:
            inner_test = fn

        wrapper.hypothesis = _Marker()
        return wrapper

    return deco
