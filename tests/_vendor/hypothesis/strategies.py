"""Strategies for the vendored hypothesis shim (see package docstring).

Each strategy generates via ``example(rnd, mode)`` where ``rnd`` is a
seeded ``random.Random`` and ``mode`` is ``'low'`` (lower bounds),
``'high'`` (upper bounds) or ``'rand'``; the bound sweeps give every
``@given`` test deterministic edge-case coverage before the random
examples.
"""

from __future__ import annotations

import random


class SearchStrategy:
    def example(self, rnd: random.Random, mode: str = "rand"):
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rnd, mode="rand"):
        if mode == "low":
            return self.lo
        if mode == "high":
            return self.hi
        return rnd.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rnd, mode="rand"):
        if mode == "low":
            return self.lo
        if mode == "high":
            return self.hi
        return rnd.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def example(self, rnd, mode="rand"):
        if mode == "low":
            return False
        if mode == "high":
            return True
        return bool(rnd.getrandbits(1))


class _Tuples(SearchStrategy):
    def __init__(self, *strats):
        self.strats = strats

    def example(self, rnd, mode="rand"):
        return tuple(s.example(rnd, mode) for s in self.strats)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size if max_size is not None
                            else min_size + 10)

    def example(self, rnd, mode="rand"):
        if mode == "low":
            n = self.min_size
        elif mode == "high":
            n = self.max_size
        else:
            n = rnd.randint(self.min_size, self.max_size)
        # element modes stay random so bound-sweep lists aren't constant
        return [self.elements.example(rnd, "rand") for _ in range(n)]


class _Randoms(SearchStrategy):
    def __init__(self, use_true_random=False):
        self.use_true_random = use_true_random

    def example(self, rnd, mode="rand"):
        if self.use_true_random:
            return random.Random()
        return random.Random(rnd.getrandbits(32))


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def floats(min_value, max_value, **_ignored):
    return _Floats(min_value, max_value)


def booleans():
    return _Booleans()


def tuples(*strats):
    return _Tuples(*strats)


def lists(elements, min_size=0, max_size=None):
    return _Lists(elements, min_size, max_size)


def randoms(use_true_random=False):
    return _Randoms(use_true_random)
