"""Mapping/dataflow co-exploration (fourth design layer) contracts.

Three invariants anchor the layer:

1. **``mapping=None`` is the pre-mapping program.** Every arm —
   costmodel full tier, explicit placement, the Pallas kernel path, the
   env — statically dispatches to the exact pre-feature expressions, so
   omitting the mapping and passing ``mapping=None`` are bitwise
   identical, and tier-1 regressions pin the unmapped numbers.

2. **The canonical mapping is an exact no-op.** ``mapping.canonical()``
   reproduces the paper's fixed weight-stationary dataflow: every
   mapped factor is exactly 1.0 and every mapped correction exactly 0.0,
   so eager evaluation is bitwise identical to ``mapping=None``. Under
   ``jit`` the unmapped program constant-folds the scalar
   ``mapping_eff`` multiply chain while the mapped program carries it as
   a traced array, so XLA may differ by ~1 ulp — tested at rtol 1e-5.

3. **Delta pricing is a faithful oracle.** Chains of fused
   mapping+placement delta updates agree with from-scratch evaluation
   of the same (placement, mapping) state on every Metrics field to
   1e-5 (the repo's established delta contract).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import mapping as mpg
from repro.core import params as ps
from repro.core import placement as pm
from repro.kernels import ops
from repro.sa import annealing as sa


def _designs(seed=0, n=16):
    return ps.random_design(jax.random.PRNGKey(seed), batch_shape=(n,))


def _assert_tree_bitwise(a, b, msg=""):
    for i, (x, y) in enumerate(zip(jax.tree_util.tree_leaves(a),
                                   jax.tree_util.tree_leaves(b))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg} (leaf {i})")


class TestMappingPytree:
    def test_canonical_shapes_and_flat_roundtrip(self):
        m = mpg.canonical(batch_shape=(3,))
        assert m.stage.shape == (3, mpg.MAX_SLOTS)
        assert m.tile_idx.shape == (3, mpg.N_LAYER_GROUPS)
        r = mpg.random_mapping(jax.random.PRNGKey(1), 9)
        back = mpg.from_flat(mpg.to_flat(r))
        _assert_tree_bitwise(r, back, "to_flat/from_flat roundtrip")

    def test_canonical_summary_is_exact_identity(self):
        """The factors the cost model multiplies in are exactly 1/0."""
        for n_pos in (1, 5, 25):
            s = mpg.traffic_summary(mpg.canonical(), jnp.int32(n_pos))
            assert float(s.recv_frac) == 0.0
            assert float(s.pull_frac) == 1.0
            assert float(s.balance) == 1.0
            assert float(s.tile_hbm) == 1.0
            assert float(s.tile_u) == 1.0


class TestCanonicalNoOp:
    def test_eager_full_tier_bitwise(self):
        dp = _designs()
        a = cm.evaluate(dp, nop_fidelity="full")
        b = cm.evaluate(dp, nop_fidelity="full",
                        mapping=mpg.canonical(batch_shape=(16,)))
        _assert_tree_bitwise(a, b, "eager full tier")

    def test_eager_explicit_placement_bitwise(self):
        dp = _designs(seed=2)
        pre = jax.vmap(lambda d: cm._eval_prefix(d, cm.hw.DEFAULT_HW))(dp)
        plc = jax.vmap(pm.canonical)(pre.mesh_m, pre.mesh_n,
                                     pre.v.hbm_mask, pre.v.arch_type)
        a = cm.evaluate(dp, placement=plc)
        b = cm.evaluate(dp, placement=plc,
                        mapping=mpg.canonical(batch_shape=(16,)))
        _assert_tree_bitwise(a, b, "eager explicit placement")

    def test_jit_full_tier_within_ulp(self):
        dp = _designs()
        a = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full"))(dp)
        b = jax.jit(lambda d, m: cm.evaluate(d, nop_fidelity="full",
                                             mapping=m))(
            dp, mpg.canonical(batch_shape=(16,)))
        for n, x, y in zip(a._fields, a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, err_msg=f"jit: {n}")

    def test_kernel_canonical_within_ulp(self):
        dp = _designs(seed=3, n=24)
        a = ops.chiplet_eval(dp, nop_fidelity="full")
        b = ops.chiplet_eval(dp, nop_fidelity="full",
                             mapping=mpg.canonical(batch_shape=(24,)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   err_msg="pallas canonical vs unmapped")


class TestNoneDispatch:
    """mapping=None and omitting the argument are the same program."""

    def test_costmodel_none_bitwise(self):
        dp = _designs()
        f = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full"))
        g = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full",
                                          mapping=None))
        _assert_tree_bitwise(f(dp), g(dp), "costmodel mapping=None")

    def test_kernel_none_bitwise(self):
        dp = _designs(seed=1)
        a = ops.chiplet_eval(dp)
        b = ops.chiplet_eval(dp, mapping=None)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_kernel_fast_tier_rejects_mapping(self):
        dp = _designs(seed=1)
        with pytest.raises(ValueError, match="canonical dataflow"):
            ops.chiplet_eval(dp, nop_fidelity="fast",
                             mapping=mpg.canonical(batch_shape=(16,)))
        with pytest.raises(ValueError, match="canonical dataflow"):
            cm.evaluate(dp, nop_fidelity="fast",
                        mapping=mpg.canonical(batch_shape=(16,)))

    def test_env_default_pytree_unchanged(self):
        """A mapping-off env episode carries no mapping state and its
        rewards match the placement-only episode bit-for-bit."""
        cfg_off = chipenv.EnvConfig(placement_episode=True)
        cfg_on = chipenv.EnvConfig(placement_episode=True,
                                   mapping_actions=True)
        key = jax.random.PRNGKey(0)
        s_off, o_off = chipenv.reset(key, cfg_off)
        s_on, o_on = chipenv.reset(key, cfg_on)
        assert s_off.mapping is None
        assert s_on.mapping is not None
        n_pl = len(ps.PLACEMENT_HEAD_SIZES)
        act = _random_actions(jax.random.fold_in(key, 1), 1, cfg_on)[0]
        s_off2, _, r_off, _, _ = chipenv.step(s_off, act[:n_pl], cfg_off)
        # a canonical-keeping mapping action: reassign slot 0 to stage 0,
        # layer group 0 to the canonical tile index
        act_canon = act.at[n_pl:].set(
            jnp.asarray([0, 0, 0, mpg.CANON_TILE], jnp.int32))
        s_on2, _, r_on, _, _ = chipenv.step(s_on, act_canon, cfg_on)
        np.testing.assert_allclose(np.asarray(r_off), np.asarray(r_on),
                                   rtol=1e-5)


def _random_actions(key, n, cfg):
    heads = jnp.asarray(chipenv.head_sizes(cfg), jnp.int32)
    return jax.random.randint(key, (n, len(heads)), 0, heads,
                              dtype=jnp.int32)


class TestDeltaOracle:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_env_mapping_delta_vs_scratch_30_steps(self, seed):
        """Fused mapping+placement delta pricing agrees with scratch
        evaluation of the same carried state on every Metrics field."""
        mk = lambda delta: chipenv.EnvConfig(placement_episode=True,
                                             mapping_actions=True,
                                             delta_eval=delta,
                                             episode_len=30)
        d_cfg, s_cfg = mk(True), mk(False)
        key = jax.random.PRNGKey(seed)
        sd, _ = chipenv.reset(key, d_cfg)
        ss, _ = chipenv.reset(key, s_cfg)
        acts = _random_actions(jax.random.fold_in(key, 1), 30, d_cfg)
        d_step = jax.jit(lambda st, a: chipenv.step(st, a, d_cfg))
        s_step = jax.jit(lambda st, a: chipenv.step(st, a, s_cfg))
        scen = d_cfg.scenario()
        for i in range(30):
            sd, od, rd, _, md = d_step(sd, acts[i])
            ss, os_, rs, _, ms = s_step(ss, acts[i])
            for field in cm.Metrics._fields:
                np.testing.assert_allclose(
                    float(getattr(md, field)), float(getattr(ms, field)),
                    rtol=1e-5, atol=1e-5,
                    err_msg=f"step {i}: {field}")
            np.testing.assert_allclose(np.asarray(od), np.asarray(os_),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"step {i}: obs")
            # independent scratch oracle on the carried state
            mo = cm.evaluate_scenario(sd.design, scen, d_cfg.hw,
                                      sd.cache.placement,
                                      mapping=sd.mapping)
            np.testing.assert_allclose(float(md.reward), float(mo.reward),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"step {i}: oracle reward")

    def test_sa_mapping_chain_reward_matches_scratch(self):
        """The co-annealing SA's best (placement, mapping) re-evaluates
        from scratch to its reported best reward."""
        cfg = sa.PlacementSAConfig(n_iters=200, p_mapping=0.3)
        dp = ps.random_design(jax.random.PRNGKey(5))
        res = sa.refine_placement(jax.random.PRNGKey(6), dp,
                                  chipenv.EnvConfig(), cfg)
        assert res.best_mapping is not None
        scen = chipenv.EnvConfig().scenario()
        m = cm.evaluate_scenario(dp, scen, chipenv.EnvConfig().hw,
                                 res.best_placement,
                                 mapping=res.best_mapping)
        np.testing.assert_allclose(float(res.best_reward), float(m.reward),
                                   rtol=1e-5, atol=1e-5)


class TestSlotRelabelInvariance:
    def test_mapped_traffic_invariant_under_active_slot_relabel(self):
        """NoP traffic under a mapping is a sum over (cell, stage)
        pairs: permuting which slot index carries which (cell, stage)
        among the active slots cannot change any stat."""
        hyp = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        dp = ps.random_design(jax.random.PRNGKey(11))
        pre = cm._eval_prefix(dp, cm.hw.DEFAULT_HW)
        v, n_pos = pre.v, int(pre.n_positions)
        plc = pm.canonical(pre.mesh_m, pre.mesh_n, v.hbm_mask, v.arch_type)
        mapping = mpg.random_mapping(jax.random.PRNGKey(12), n_pos)

        @given(st.randoms(use_true_random=False))
        @settings(max_examples=10, deadline=None)
        def check(rng):
            perm = list(range(n_pos))
            rng.shuffle(perm)
            perm = np.asarray(perm + list(range(n_pos, mpg.MAX_SLOTS)))
            plc_p = plc._replace(
                chiplet_cell=plc.chiplet_cell[perm])
            map_p = mapping._replace(stage=mapping.stage[perm])
            a = pm.nop_stats(plc, n_pos, v.hbm_mask, v.arch_type,
                             mapping=mapping)
            b = pm.nop_stats(plc_p, n_pos, v.hbm_mask, v.arch_type,
                             mapping=map_p)
            for f, x, y in zip(a._fields, a, b):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           rtol=1e-5, atol=1e-6,
                                           err_msg=f"relabel: {f}")

        check()
