"""Record the placement-SA best-so-far trajectory as a regression oracle.

Writes ``tests/data_sa_trajectory.json``: the full ``PlacementResult``
history of ``sa.refine_placement[_scenarios]`` on two fixed protocols
(one scenario-batched run under the placement-sensitive preset, one
single-design run at default calibration). ``tests/test_placement_delta.py``
asserts the delta-evaluated SA reproduces these trajectories bit-for-bit
— re-run this script only when the accept/reject semantics are
*intentionally* changed (and say so in the PR).

    PYTHONPATH=src python scripts/record_sa_trajectory.py
"""

import json
import os

import jax
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import workload as wl
from repro.sa import annealing as sa

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    os.pardir, "tests", "data_sa_trajectory.json")

# Protocol constants — mirrored by tests/test_placement_delta.py.
SUITE_WORKLOADS = ("resnet50", "bert", "maskrcnn", "3dunet")
SUITE_DESIGN_SEED, SUITE_KEY_SEED = 42, 7
SINGLE_DESIGN_SEED, SINGLE_KEY_SEED = 4, 5
N_ITERS, RECORD_EVERY = 400, 20


def _sa_cfg(**kw):
    # the oracle is the FULL-recompute trajectory (the semantic
    # definition); the delta path must reproduce it bit-for-bit
    return sa.PlacementSAConfig(n_iters=N_ITERS, record_every=RECORD_EVERY,
                                delta_eval=False, **kw)


def main():
    from repro.optimizer import scenario as suite

    # --- scenario-batched, placement-sensitive preset ----------------------
    env_sens = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
    scen = cm.stack_scenarios([
        cm.Scenario(workload=wl.MLPERF[n]) for n in SUITE_WORKLOADS])
    dps = ps.random_design(jax.random.PRNGKey(SUITE_DESIGN_SEED),
                           (len(SUITE_WORKLOADS),))
    res = sa.refine_placement_scenarios(
        jax.random.PRNGKey(SUITE_KEY_SEED), dps, scen, env_sens, _sa_cfg())

    # --- single design, default calibration --------------------------------
    dp1 = ps.random_design(jax.random.PRNGKey(SINGLE_DESIGN_SEED))
    res1 = sa.refine_placement(
        jax.random.PRNGKey(SINGLE_KEY_SEED), dp1, chipenv.EnvConfig(),
        _sa_cfg())

    record = {
        "n_iters": N_ITERS,
        "record_every": RECORD_EVERY,
        "suite": {
            "workloads": list(SUITE_WORKLOADS),
            "design_seed": SUITE_DESIGN_SEED,
            "key_seed": SUITE_KEY_SEED,
            "history": np.asarray(res.history, np.float64).tolist(),
            "best_reward": np.asarray(res.best_reward, np.float64).tolist(),
            "canonical_reward": np.asarray(res.canonical_reward,
                                           np.float64).tolist(),
            "best_cells": np.asarray(
                res.best_placement.chiplet_cell).tolist(),
            "best_hbm_ij": np.asarray(res.best_placement.hbm_ij,
                                      np.float64).tolist(),
        },
        "single": {
            "design_seed": SINGLE_DESIGN_SEED,
            "key_seed": SINGLE_KEY_SEED,
            "history": np.asarray(res1.history, np.float64).tolist(),
            "best_reward": float(res1.best_reward),
            "canonical_reward": float(res1.canonical_reward),
            "best_cells": np.asarray(
                res1.best_placement.chiplet_cell).tolist(),
            "best_hbm_ij": np.asarray(res1.best_placement.hbm_ij,
                                      np.float64).tolist(),
        },
    }
    with open(_OUT, "w") as f:
        json.dump(record, f)
        f.write("\n")
    print(f"wrote {os.path.normpath(_OUT)}")
    print(f"suite best: {record['suite']['best_reward']}")
    print(f"single best: {record['single']['best_reward']:.6f}")


if __name__ == "__main__":
    main()
