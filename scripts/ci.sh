#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + the portfolio-engine smoke benchmark.
#
#   bash scripts/ci.sh
#
# Mirrors ROADMAP.md's tier-1 verify command. hypothesis is optional
# (tests/test_properties.py skips itself when it is missing).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest (kernel parity runs as its own stage below) ==="
python -m pytest -q --ignore=tests/test_kernels.py

echo "=== kernel parity: Pallas interpret mode vs jnp oracles ==="
# CPU-only runners still verify the TPU kernels (incl. the extended
# chiplet_eval placement metrics) — interpret=True throughout.
python -m pytest -q tests/test_kernels.py

echo "=== smoke: portfolio engine benchmark ==="
python benchmarks/bench_optimizer.py --smoke

echo "=== smoke: cost-model eval throughput ==="
# CI-scale smoke run; the committed BENCH_costmodel.json before/after
# record is produced by the default full-batch invocation.
python benchmarks/bench_costmodel.py --batch 16384 \
    --out "${TMPDIR:-/tmp}/bench_costmodel_ci.json"
