#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + the portfolio-engine smoke benchmark.
#
#   bash scripts/ci.sh
#
# Mirrors ROADMAP.md's tier-1 verify command. hypothesis is optional
# (tests/test_properties.py skips itself when it is missing).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -q

echo "=== smoke: portfolio engine benchmark ==="
python benchmarks/bench_optimizer.py --smoke
