#!/usr/bin/env bash
# CI entrypoint: tier-1 tests + the portfolio-engine smoke benchmark.
#
#   bash scripts/ci.sh
#
# Mirrors ROADMAP.md's tier-1 verify command. hypothesis is optional:
# when the real wheel is missing, tests/conftest.py exposes the vendored
# shim in tests/_vendor/ so the property suites (tests/test_properties.py,
# the hypothesis half of tests/test_evo.py) EXECUTE instead of skipping.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 fast lane: pytest -m 'not slow' ==="
# the ~2-min-each multi-device subprocess cases (tests/test_distributed.py)
# are marked slow and run in their own stage below, keeping this loop fast
python -m pytest -q -m "not slow" --ignore=tests/test_kernels.py

echo "=== kernel parity: Pallas interpret mode vs jnp oracles ==="
# CPU-only runners still verify the TPU kernels (incl. the fast-tier and
# full-tier chiplet_eval NoP paths) — interpret=True throughout.
python -m pytest -q tests/test_kernels.py

echo "=== slow lane: multi-device subprocess tests ==="
python -m pytest -q -m slow

echo "=== smoke: portfolio engine benchmark (+ evo-arm archive guard) ==="
# --assert-evo-hv (ISSUE-5): on a fixed seed, the three-arm (SA+RL+evo)
# MLPerf smoke suite must beat or tie the SA+RL-only suite on every
# scenario's winner reward AND on the shared-ref archive hypervolume.
# Both hold by construction (the SA/RL key streams don't depend on
# n_evo, per-arm lockstep refinement only grows the candidate set, and
# the bench archive capacity is large enough that no eviction occurs),
# so a failure means the superset contract was broken.
python benchmarks/bench_optimizer.py --smoke --assert-evo-hv

echo "=== smoke: surrogate ranker guards (ISSUE-6) ==="
# --assert-surrogate: (a) held-out Spearman(surrogate, analytic fast
# tier) >= 0.8; (b) the analytic argmax of a fresh 64k pool is inside
# the surrogate top-k (the exactness guard's re-score recovers it);
# (c) surrogate-ranked candidates/s >= 10x the analytic fast tier's on
# the same pool, both timed in this run; (d) the MLPerf smoke suite
# with the surrogate stage never loses a scenario winner to the PR-5
# three-arm baseline on the same key (holds by construction — the
# stage folds its own key and every winner is analytic-scored).
python benchmarks/bench_optimizer.py --surrogate --assert-surrogate \
    --out "${TMPDIR:-/tmp}/bench_surrogate_ci.json"

echo "=== smoke: traffic-trace guards (ISSUE-8 / ROADMAP-3) ==="
# --assert-trace: (a) evaluate_trace's per-trace-step eval rate must
# stay >= 0.5x the point-scenario rate (the whole 32-step trace vmaps
# into ONE compiled program — measured ~26x per step on this box, the
# batch amortizes per-call dispatch); (b) the flat and bursty traces
# must pick different winning designs on at least one placement-
# sensitive smoke scenario (the SLO-attainment channel rewards
# throughput headroom plain Eq.-17 scoring never sees).
python benchmarks/bench_optimizer.py --smoke --trace --assert-trace \
    --out "${TMPDIR:-/tmp}/bench_trace_ci.json"

echo "=== smoke: cost-model eval throughput (fast-tier + delta-SA guards) ==="
# CI-scale smoke run with the two-tier throughput guard: fails if the
# closed-form fast tier drops below 1.8x the full pairwise tier's
# designs/s (the committed BENCH_costmodel.json records the full-batch
# fast/full numbers this ratio protects). The committed record is
# produced by the default full-batch invocation.
#
# Delta-vs-full placement-SA guards (ISSUE-4): the delta-evaluated SA
# step must (a) compile to substantially fewer kernels than the
# full-recompute step (deterministic structural guard; measured 1.92x
# at the smoke protocol, 2.3-2.7x at larger widths) and (b) beat the
# full-recompute step's wall-clock throughput (x1.05 floor on the
# relocation phase; typically 1.2-2.5x there). The ISSUE's >=3x
# wall-clock target assumed the pre-PR-3 unfused full tier; after
# PR 3's fused scans the remaining wall gap on this launch-bound
# 2-core container is smaller (honest numbers + kernel counts in
# BENCH_costmodel.json's placement_sa_step). The run also hard-fails
# if the delta rewards diverge materially from the full-recompute
# path at the bench protocol (bitwise identity is asserted by the
# tier-1 trajectory tests; the bench records it as a flag).
#
# ISSUE-7 hot-path guards: (c) phase-scheduled SA must beat the mixed
# delta stream's wall clock (x1.25 floor; measured 1.53x at the full
# protocol — the ISSUE's 2x target is out of reach on this 2-core
# container because the mixed stream's fused move_kinds='both' delta
# already shares most kernels with the pinned segments; vs the PR-4
# recorded mixed-delta baseline of 101,723 steps/s the phased path is
# ~4x, but that spans machine conditions so it is not gated); and
# (d) delta-priced placement-episode env stepping must deliver >= 2.5x
# the cache-free scratch rollout's steps/s (measured 3.31x end to end:
# ~2.3x from the cond-gated vectorized auto-reset that stops rebuilding
# the placement context every step, ~1.44x from delta pricing on top).
# The run also hard-fails if the delta env rewards diverge from either
# scratch stream at 1e-5.
#
# ISSUE-10 telemetry guards (--assert-telemetry): (e) telemetry=False
# must be BITWISE identical to the pre-telemetry program — same phased-SA
# trajectories AND the same compiled while-body kernel count (counted by
# the shared telemetry/profile.py counter the other guards use); (f) the
# counters-on run must leave trajectories bitwise unchanged (counters
# only read already-computed values), its counter totals must match the
# proposal ledger exactly, and its wall overhead must stay <= 1.15x the
# off path (measured 1.03x at the smoke protocol).
python benchmarks/bench_costmodel.py --smoke --assert-min-ratio 1.8 \
    --assert-min-sa-ratio 1.05 --assert-min-sa-kernel-ratio 1.7 \
    --assert-min-phased-sa-ratio 1.25 --assert-min-env-step-ratio 2.5 \
    --assert-telemetry \
    --out "${TMPDIR:-/tmp}/bench_costmodel_ci.json"

echo "=== smoke: mapping-layer guards (fourth design layer) ==="
# (a) mapping=None must stay bit-exact: the jitted full-tier evaluate
#     with mapping=None compiles the identical pre-mapping program, so
#     every Metrics leaf on a 4k random batch must match the no-kwarg
#     call bitwise; (b) the mapping-enabled smoke suite (MAPPING_SMOKE)
#     must never lose a scenario winner to the three-layer
#     placement-sensitive baseline on the same key — holds by
#     construction (the mapping stage folds its own key stream,
#     fold_in(key, 8), and swaps a mapped candidate in only on strict
#     improvement), so a failure means that contract was broken.
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np, sys
from repro.core import costmodel as cm, params as ps
from repro.optimizer import scenario as suite

dp = ps.random_design(jax.random.PRNGKey(0), (4096,))
a = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full"))(dp)
b = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full",
                                  mapping=None))(dp)
for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
    if not bool(jnp.array_equal(x, y)):
        print("[ci] FAIL: mapping=None is not bit-exact with the "
              "pre-mapping full tier", file=sys.stderr)
        sys.exit(1)
print("[ci] mapping=None bit-exact on the full tier (4096 designs)")

key = jax.random.PRNGKey(0)
base = suite.run_suite(key, suite.PLACEMENT_SENSITIVE_SMOKE)
mapped = suite.run_suite(key, suite.MAPPING_SMOKE)
worse = []
for ob, om in zip(base.outcomes, mapped.outcomes):
    if om.best_reward < ob.best_reward - 1e-6:
        worse.append((om.name, om.best_reward, ob.best_reward))
if worse:
    print(f"[ci] FAIL: mapping-enabled suite lost winners: {worse}",
          file=sys.stderr)
    sys.exit(1)
gains = [om.best_reward - ob.best_reward
         for ob, om in zip(base.outcomes, mapped.outcomes)]
print(f"[ci] mapping suite never-worse on {len(gains)} scenarios "
      f"(mean gain {np.mean(gains):+.3f}, max {np.max(gains):+.3f})")
PY
