"""Render a telemetry run journal into a human-readable run summary.

Reads the JSONL journal written by ``--telemetry out.jsonl`` (see
``repro.telemetry.journal``) and prints: the environment fingerprint,
the span tree with wall-clock durations, per-arm convergence (best
reward + sparkline curve), placement-SA acceptance rates/curves, GA
archive hypervolume over generations, PPO update stats, surrogate
fit/rank-drift events, compile timings, and the suite-level archive /
winners summary.

    PYTHONPATH=src python scripts/telemetry_report.py /tmp/run.jsonl
"""

import argparse
import sys

from repro.telemetry import journal as tj

_TICKS = "▁▂▃▄▅▆▇█"


def sparkline(values, width=48):
    """Unicode sparkline of a numeric sequence (downsampled to width)."""
    vals = [float(v) for v in values
            if v is not None and v == v and abs(float(v)) != float("inf")]
    if not vals:
        return "(no finite samples)"
    if len(vals) > width:
        stride = len(vals) / width
        vals = [vals[int(i * stride)] for i in range(width)]
    lo, hi = min(vals), max(vals)
    if hi <= lo:
        return _TICKS[0] * len(vals) + f"  [{lo:.4g}]"
    chars = "".join(_TICKS[int((v - lo) / (hi - lo) * (len(_TICKS) - 1))]
                    for v in vals)
    return f"{chars}  [{lo:.4g} .. {hi:.4g}]"


def _fmt_dur(s):
    return f"{s:.1f}s" if s >= 1 else f"{s * 1e3:.0f}ms"


def _span_tree(records):
    """Closed spans in order, with depth from their parent chain."""
    spans = [r for r in records if r.get("kind") == "span"]
    depth = {}
    out = []
    for r in spans:
        d = depth.get(r.get("parent"), -1) + 1 if r.get("parent") else 0
        depth[r["name"]] = d
        out.append((d, r))
    return out


def _accept_rate_curve(ev):
    """Per-record acceptance rate from a cumulative accept curve."""
    curve = ev.get("accept_curve")
    if not curve or len(curve) < 2:
        return None
    total = sum(ev.get("propose", [])) or 1
    stride = total / (len(curve) - 1)
    rates = []
    for i in range(1, len(curve)):
        rates.append((curve[i] - curve[i - 1]) / max(stride, 1))
    return rates


def render(records, out=sys.stdout):
    w = out.write
    by_name = {}
    for r in records:
        if r.get("kind") == "event":
            by_name.setdefault(r["name"], []).append(r)

    begin = next((r for r in records if r.get("kind") == "run_begin"), None)
    end_ts = max((r["ts"] for r in records if "ts" in r), default=None)
    w("telemetry run report\n====================\n")
    if begin:
        env = begin.get("env", {})
        w(f"run:      {begin.get('run')}\n")
        if end_ts is not None:
            w(f"wall:     {_fmt_dur(end_ts - begin['ts'])}\n")
        w(f"env:      python {env.get('python')}, jax {env.get('jax')} "
          f"({env.get('backend')}, {env.get('device_count')} device(s), "
          f"{env.get('cpu_count')} cpus)\n")
        w(f"platform: {env.get('platform')}\n")

    cfgs = by_name.get("suite_config", [])
    for c in cfgs:
        w(f"\nsuite: {c.get('n_scenarios')} scenario(s), "
          f"arms sa={c.get('n_sa')} rl={c.get('n_rl')} evo={c.get('n_evo')}"
          f", surrogate={'on' if c.get('surrogate') else 'off'}"
          f", mapping={'on' if c.get('mapping_refine') else 'off'}"
          f", trace={c.get('trace') or 'off'}\n")

    tree = _span_tree(records)
    if tree:
        w("\nstages\n------\n")
        for d, r in tree:
            extras = {k: v for k, v in r.items()
                      if k not in ("ts", "run", "kind", "name", "parent",
                                   "dur_s")}
            meta = ", ".join(f"{k}={v}" for k, v in extras.items())
            w(f"  {'  ' * d}{r['name']:<{24 - 2 * d}} "
              f"{_fmt_dur(r['dur_s']):>8}   {meta}\n")

    conv = by_name.get("arm_convergence", [])
    if conv:
        w("\nper-arm convergence (best-so-far reward)\n"
          "----------------------------------------\n")
        for ev in conv:
            curve = ev.get("curve") or []
            # scenario suites log (S, T) curves; portfolios log (T,)
            curves = curve if curve and isinstance(curve[0], list) \
                else [curve]
            best = ev.get("best") or []
            if not isinstance(best, list):
                best = [best]
            for s, c in enumerate(curves):
                tag = f"[{s}]" if len(curves) > 1 else ""
                b = f"{best[s]:.1f}" if s < len(best) else "?"
                w(f"  {ev['arm']:<4}{tag:<5} best={b:>9}  {sparkline(c)}\n")

    acc = by_name.get("sa_accept", [])
    if acc:
        w("\nplacement-SA acceptance\n-----------------------\n")
        for ev in acc:
            scen = ev.get("scenario", "")
            rates = ", ".join(f"{r:.2f}" for r in ev.get("accept_rate", []))
            seg = ev.get("seg_accept_rate", [])
            segs = ("" if len(seg) <= 1 else
                    "  segments [" + ", ".join(f"{r:.2f}" for r in seg)
                    + "]")
            w(f"  {scen or ev.get('stage', '?')}: accept-rate/kind "
              f"[{rates}] improve={ev.get('improve')}{segs}\n")
            rc = _accept_rate_curve(ev)
            if rc:
                w(f"    accept-rate over run: {sparkline(rc)}\n")

    adapt = by_name.get("sa_adapt", [])
    for ev in adapt:
        w(f"\nadaptive SA schedule ({ev.get('rounds')} rounds): "
          f"{ev.get('schedules')}\n")

    evo = by_name.get("evo_stats", [])
    if evo:
        w("\nGA generation stats\n-------------------\n")
        for ev in evo:
            hv = ev.get("archive_hv") or []
            hvs = hv if hv and isinstance(hv[0], list) else [hv]
            div = ev.get("diversity") or []
            divs = div if div and isinstance(div[0], list) else [div]
            for s, c in enumerate(hvs):
                tag = f"[{s}]" if len(hvs) > 1 else ""
                w(f"  archive HV{tag:<5} {sparkline(c)}\n")
            for s, c in enumerate(divs):
                tag = f"[{s}]" if len(divs) > 1 else ""
                w(f"  diversity {tag:<5} {sparkline(c)}\n")

    ppo = by_name.get("ppo_stats", [])
    if ppo:
        w("\nPPO update stats\n----------------\n")
        for ev in ppo:
            for k in ("entropy", "approx_kl", "clip_frac", "return_mean"):
                c = ev.get(k) or []
                cs = c if c and isinstance(c[0], list) else [c]
                for s, cc in enumerate(cs):
                    tag = f"[{s}]" if len(cs) > 1 else ""
                    w(f"  {k:<12}{tag:<5} {sparkline(cc)}\n")

    fits = by_name.get("surrogate_fit", [])
    drifts = by_name.get("surrogate_rank_drift", [])
    boots = by_name.get("surrogate_bootstrap", [])
    if fits or boots:
        w("\nsurrogate\n---------\n")
        for ev in boots:
            w(f"  bootstrap: {ev.get('n')} analytic evals "
              f"(+{ev.get('tap_rows')} tapped) -> "
              f"{ev.get('dataset_rows')} dataset rows\n")
        for ev in fits:
            w(f"  fit @ chunk {ev.get('chunk')}: "
              f"{ev.get('dataset_rows')} dataset rows\n")
        for ev in drifts:
            w(f"  rank drift @ chunk {ev.get('chunk')}: "
              f"spearman {ev.get('spearman'):.3f} vs previous fit\n")

    compiles = by_name.get("compile", [])
    if compiles:
        w("\ncompile events\n--------------\n")
        for ev in compiles:
            w(f"  {ev.get('target', '?'):<32} "
              f"{_fmt_dur(ev.get('dur_s', 0))}\n")

    arch = by_name.get("suite_archive", [])
    for ev in arch:
        w(f"\nsuite archive: {ev.get('n_points')} non-dominated points "
          f"(capacity {ev.get('capacity')}), "
          f"hypervolume {ev.get('hypervolume'):.4g}\n")

    winners = by_name.get("suite_end", [])
    for ev in winners:
        w("\nwinners\n-------\n")
        for row in ev.get("winners", []):
            w(f"  {row.get('scenario', ''):<43} "
              f"{row.get('reward', 0.0):>9.1f}  {row.get('source')}\n")
        w(f"\nsuite wall-time {_fmt_dur(ev.get('wall_time_s', 0))}\n")
    for ev in by_name.get("portfolio_end", []):
        w(f"\nportfolio winner: reward {ev.get('best_reward'):.1f} "
          f"({ev.get('source')}), placement {ev.get('placement_reward')}, "
          f"wall {_fmt_dur(ev.get('wall_time_s', 0))}\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("journal", help="JSONL journal from --telemetry")
    args = ap.parse_args()
    render(tj.load(args.journal))


if __name__ == "__main__":
    main()
