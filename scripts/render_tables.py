"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results JSONs.

    PYTHONPATH=src python scripts/render_tables.py results/dryrun
"""

import glob
import json
import os
import sys


def load(out_dir):
    rows = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        if "__opt" in p or "__base" in p:
            continue
        rows.append(json.load(open(p)))
    return rows


def dryrun_table(rows):
    print("\n### Dry-run matrix (status / per-device temp memory / "
          "compile time)\n")
    print("| arch | shape | 16x16 (256 chips) | 2x16x16 (512 chips) |")
    print("|---|---|---|---|")
    cells = {}
    for r in rows:
        if r["arch"] == "chipletgym":
            continue
        key = (r["arch"], r["shape"])
        mesh = "single" if r["mesh"] == "pod16x16" else "multi"
        if r["status"] == "ok":
            import re
            m = re.search(r"temp_size_in_bytes=(\d+)", r["memory_analysis"])
            tmp = int(m.group(1)) / 2**30 if m else 0
            txt = f"ok ({tmp:.1f} GiB tmp, {r['compile_s']:.0f}s)"
        elif r["status"] == "skipped":
            txt = "skip (full attention)"
        else:
            txt = "ERROR"
        cells.setdefault(key, {})[mesh] = txt
    for (arch, shape), d in sorted(cells.items()):
        print(f"| {arch} | {shape} | {d.get('single','-')} "
              f"| {d.get('multi','-')} |")
    chip = [r for r in rows if r["arch"] == "chipletgym"]
    for r in chip:
        print(f"| chipletgym (PPO update) | rl_rollout | "
              f"{'ok' if r['mesh']=='pod16x16' and r['status']=='ok' else ''} "
              f"| {'ok' if r['mesh']=='pod2x16x16' and r['status']=='ok' else ''} |"
              if False else "", end="")
    print(f"\nchipletgym PPO update: "
          + ", ".join(f"{r['mesh']}={r['status']}" for r in chip))


def roofline_table(rows, mesh="pod16x16"):
    print(f"\n### Roofline ({mesh}, per chip: 197 TF/s bf16, 819 GB/s HBM,"
          " 3x50 GB/s ICI)\n")
    print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck "
          "| 6ND/HLO | roofline frac | dominant collective |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"])):
        if r["status"] != "ok" or r["arch"] == "chipletgym" \
                or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        coll = rf.get("collective_breakdown", {})
        dom_coll = max(coll, key=coll.get) if coll else "-"
        print(f"| {r['arch']} | {r['shape']} "
              f"| {rf['t_compute']*1e3:.1f} | {rf['t_memory']*1e3:.1f} "
              f"| {rf['t_collective']*1e3:.1f} | {rf['bottleneck']} "
              f"| {rf['useful_ratio']:.2f} "
              f"| {rf['roofline_fraction']:.1%} | {dom_coll} |")


if __name__ == "__main__":
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load(out_dir)
    dryrun_table(rows)
    roofline_table(rows, "pod16x16")
    roofline_table(rows, "pod2x16x16")
